//! Crate-wide call graph over the per-file models.
//!
//! Name resolution is deliberately token-level and heuristic — no
//! trait solver, no type inference engine. A call site resolves by
//! callee name, narrowed by a receiver type when one is derivable
//! from (in order) parameter annotations, `let` bindings
//! (`let x: T` / `let x = T::new(` / `T { .. }`), the crate-wide
//! struct-field map, or the enclosing `impl` block for `self`. Three
//! precision rules keep the over-approximation from drowning the
//! passes in std-prelude noise (measured on this tree: 139 spurious
//! frontier findings without them, 0 with):
//!
//! 1. A typed receiver with *no* impl of that name in the crate means
//!    the call targets a std/extern type — no edges.
//! 2. A typed receiver whose only matches are bodiless trait
//!    declarations is dyn/impl-Trait dispatch — fall back to every
//!    same-name implementation.
//! 3. An *untyped* receiver only resolves names that do not collide
//!    with the std prelude ([`UNTYPED_SKIP`]); `get`, `new`, `clone`
//!    et al. need a typed receiver to produce edges.
//!
//! Everything else resolves to all same-name non-test functions (an
//! over-approximation: the obligation passes prefer false edges over
//! missed panics).

use std::collections::{HashMap, HashSet};

use super::lexer::{Tok, TokKind};
use super::model::{FileModel, FnInfo};

/// Reserved words that look like calls (`if (`, `while (`, ...).
const RUST_KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "else", "let", "fn", "move", "in", "as",
    "ref", "mut", "pub", "use", "mod", "impl", "trait", "struct", "enum", "where", "unsafe",
    "async", "await", "dyn", "box",
];

/// Deref-transparent wrappers skipped when extracting a type name:
/// `Arc<Runtime>`, `Option<ThreadPool>` etc. type as the inner ident.
const TYPE_WRAPPERS: &[&str] = &[
    "mut", "dyn", "impl", "Arc", "Rc", "Box", "RefCell", "Cell", "Mutex", "RwLock", "Weak",
    "Cow", "Option", "Result",
];

/// Method names shared with std-prelude APIs: resolving these through
/// an unknown receiver links `HashMap::get` to our `Weights::get`
/// etc., so they only resolve when the receiver type is known.
const UNTYPED_SKIP: &[&str] = &[
    "new", "default", "get", "get_mut", "insert", "remove", "push", "pop", "clone", "collect",
    "next", "len", "is_empty", "extend", "take", "entry", "iter", "into_iter", "unwrap",
    "expect", "contains", "contains_key", "clear", "drain", "to_vec", "min", "max", "map",
    "and_then", "filter", "find", "sum", "last", "first", "split", "parse", "from", "build",
    "write", "read", "send", "recv", "lock", "join", "abs", "sort", "sort_by", "retain",
    "resize", "rev", "get_or",
];

fn is_wrapper(s: &str) -> bool {
    TYPE_WRAPPERS.contains(&s)
}

/// `toks[i]` is a call of *some* function: ident + `(`, not a `fn`
/// definition.
fn is_call_at(toks: &[Tok], i: usize) -> bool {
    toks[i].kind == TokKind::Ident
        && toks.get(i + 1).map(|t| t.is_punct('(')).unwrap_or(false)
        && !(i > 0 && toks[i - 1].is_ident("fn"))
}

/// One unresolved call site inside a function body.
#[derive(Debug, Clone)]
struct CallSite {
    callee: String,
    recv_type: Option<String>,
    line: u32,
}

/// A call site with its resolved target node indices.
#[derive(Debug, Clone)]
pub struct ResolvedSite {
    pub callee: String,
    pub line: u32,
    pub targets: Vec<usize>,
}

/// One function in the program. `file_ix`/`fn_ix` index back into the
/// model slice the graph was built from; name/test/body facts are
/// cached here so the passes rarely need the round trip.
#[derive(Debug)]
pub struct FnNode {
    pub file_ix: usize,
    pub fn_ix: usize,
    pub name: String,
    pub line: u32,
    pub is_test: bool,
    pub is_hot: bool,
    pub has_body: bool,
    pub impl_type: Option<String>,
    /// Every call site with its resolved targets, body order.
    pub resolved_sites: Vec<ResolvedSite>,
    /// Deduplicated, sorted union of all targets (the adjacency list).
    pub resolved: Vec<usize>,
}

/// The crate-wide graph: one node per extracted fn, edges from the
/// heuristic resolution above.
pub struct CallGraph {
    pub nodes: Vec<FnNode>,
    index: HashMap<(usize, usize), usize>,
    /// Reverse adjacency (callers), same indices.
    rev: Vec<Vec<usize>>,
}

/// Parameter name -> first non-wrapper type ident, from the signature
/// token range.
fn param_types(m: &FileModel, f: &FnInfo) -> HashMap<String, String> {
    let toks = &m.toks;
    let mut out = HashMap::new();
    // First `(` of the signature opens the param list.
    let mut i = f.start;
    while i < f.sig_end && !toks[i].is_punct('(') {
        i += 1;
    }
    if i >= f.sig_end {
        return out;
    }
    let mut depth = 1isize;
    i += 1;
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    while i < f.sig_end && depth > 0 {
        let t = &toks[i];
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        if t.is_punct(',') && depth == 1 {
            groups.push(std::mem::take(&mut cur));
        } else {
            cur.push(i);
        }
        i += 1;
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    for g in groups {
        // Pattern: `[mut] name : TYPE...` — the `:` at the top level
        // (`::` pairs are skipped).
        let mut ci: Option<usize> = None;
        let mut k = 0usize;
        while k < g.len() {
            if toks[g[k]].is_punct(':') {
                if k + 1 < g.len() && toks[g[k + 1]].is_punct(':') {
                    k += 2;
                    continue;
                }
                ci = Some(k);
                break;
            }
            k += 1;
        }
        let Some(ci) = ci else { continue };
        if ci == 0 {
            continue;
        }
        let name_tok = &toks[g[ci - 1]];
        if name_tok.kind != TokKind::Ident {
            continue;
        }
        let mut ty: Option<String> = None;
        for &gi in &g[ci + 1..] {
            let t = &toks[gi];
            if t.kind == TokKind::Ident && !is_wrapper(&t.text) {
                ty = Some(t.text.clone());
                break;
            }
            if t.kind == TokKind::Ident
                || t.kind == TokKind::Lifetime
                || t.is_punct('&')
                || t.is_punct('<')
            {
                continue;
            }
            if t.is_punct('[') || t.is_punct('(') {
                break;
            }
        }
        if let Some(ty) = ty {
            out.insert(name_tok.text.clone(), ty);
        }
    }
    out
}

/// `let x: Type` / `let x = Type::new(` / `let x = Type { ..` bindings
/// inside the body.
fn local_types(m: &FileModel, f: &FnInfo) -> HashMap<String, String> {
    let toks = &m.toks;
    let (s, e) = (f.body.start, f.body.end);
    let mut out = HashMap::new();
    let mut i = s;
    while i < e {
        if !toks[i].is_ident("let") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if j < e && toks[j].is_ident("mut") {
            j += 1;
        }
        if j < e && toks[j].kind == TokKind::Ident {
            let var = toks[j].text.clone();
            let k = j + 1;
            if k < e
                && toks[k].is_punct(':')
                && k + 1 < e
                && !toks[k + 1].is_punct(':')
            {
                // `let x: Type`
                let mut mm = k + 1;
                while mm < e {
                    let t = &toks[mm];
                    if t.kind == TokKind::Ident && !is_wrapper(&t.text) {
                        out.insert(var.clone(), t.text.clone());
                        break;
                    }
                    if t.is_punct('&')
                        || t.kind == TokKind::Lifetime
                        || t.is_punct('<')
                        || t.kind == TokKind::Ident
                    {
                        mm += 1;
                        continue;
                    }
                    break;
                }
            } else if k < e && toks[k].is_punct('=') {
                // `let x = Type::new(...)` / `Type { .. }`
                let mm = k + 1;
                if mm < e
                    && toks[mm].kind == TokKind::Ident
                    && toks[mm].text.chars().next().map(|c| c.is_uppercase()).unwrap_or(false)
                {
                    let path = mm + 2 < e
                        && toks[mm + 1].is_punct(':')
                        && toks[mm + 2].is_punct(':');
                    let brace = mm + 1 < e && toks[mm + 1].is_punct('{');
                    if path || brace {
                        out.insert(var, toks[mm].text.clone());
                    }
                }
            }
        }
        i += 1;
    }
    out
}

/// Crate-wide `field name -> type name` map from struct bodies.
/// Field names declared with different types in different structs are
/// ambiguous and dropped.
fn field_types(models: &[FileModel]) -> HashMap<String, Option<String>> {
    let mut out: HashMap<String, Option<String>> = HashMap::new();
    for m in models {
        let toks = &m.toks;
        let mut i = 0usize;
        while i < toks.len() {
            if !toks[i].is_ident("struct") {
                i += 1;
                continue;
            }
            // Walk to `{` (tuple/unit structs end with `(` or `;`).
            let mut j = i + 1;
            let mut found = false;
            while j < toks.len() {
                if toks[j].is_punct('{') {
                    found = true;
                    break;
                }
                if toks[j].is_punct(';') || toks[j].is_punct('(') {
                    break;
                }
                j += 1;
            }
            if !found {
                i = j + 1;
                continue;
            }
            // Entries `[pub] name : Type ,` at depth 1 (angles count
            // as depth so generic defaults don't look like fields).
            let mut d = 1isize;
            let mut k = j + 1;
            while k < toks.len() && d > 0 {
                let t = &toks[k];
                if t.is_punct('{') || t.is_punct('<') {
                    d += 1;
                } else if t.is_punct('}') || t.is_punct('>') {
                    d -= 1;
                } else if d == 1
                    && t.kind == TokKind::Ident
                    && toks.get(k + 1).map(|n| n.is_punct(':')).unwrap_or(false)
                    && !toks.get(k + 2).map(|n| n.is_punct(':')).unwrap_or(false)
                {
                    let fname = t.text.clone();
                    let mut ty: Option<String> = None;
                    let mut x = k + 2;
                    while x < toks.len() {
                        let tx = &toks[x];
                        if tx.kind == TokKind::Ident && !is_wrapper(&tx.text) {
                            ty = Some(tx.text.clone());
                            break;
                        }
                        if tx.is_punct('&')
                            || tx.kind == TokKind::Lifetime
                            || tx.is_punct('<')
                            || tx.kind == TokKind::Ident
                        {
                            x += 1;
                            continue;
                        }
                        break;
                    }
                    if let Some(ty) = ty {
                        match out.get(&fname) {
                            Some(Some(prev)) if *prev != ty => {
                                out.insert(fname, None); // ambiguous
                            }
                            Some(_) => {}
                            None => {
                                out.insert(fname, Some(ty));
                            }
                        }
                    }
                }
                k += 1;
            }
            i = k;
        }
    }
    out
}

/// All call sites in a fn body with receiver type hints.
fn extract_calls(
    m: &FileModel,
    f: &FnInfo,
    fields: &HashMap<String, Option<String>>,
) -> Vec<CallSite> {
    let toks = &m.toks;
    let (s, e) = (f.body.start, f.body.end);
    let params = param_types(m, f);
    let locals = local_types(m, f);
    let field_of = |name: &str| fields.get(name).and_then(|t| t.clone());
    let var_type = |name: &str| -> Option<String> {
        if name == "self" || name == "Self" {
            return f.impl_type.clone();
        }
        locals.get(name).or_else(|| params.get(name)).cloned()
    };
    let mut out = Vec::new();
    for i in s..e {
        if !is_call_at(toks, i) {
            continue;
        }
        let name = toks[i].text.clone();
        if RUST_KEYWORDS.contains(&name.as_str()) {
            continue;
        }
        let mut recv: Option<String> = None;
        if i >= 2 && toks[i - 1].is_punct('.') && toks[i - 2].kind == TokKind::Ident {
            // Method call: `x.name(` / `self.name(` / `a.b.name(`.
            let base = &toks[i - 2].text;
            if i >= 3 && toks[i - 3].is_punct('.') {
                // Field chain `a.b.name(` — type of field `b`.
                recv = field_of(base);
            } else {
                recv = var_type(base).or_else(|| field_of(base));
            }
        } else if i >= 3
            && toks[i - 1].is_punct(':')
            && toks[i - 2].is_punct(':')
            && toks[i - 3].kind == TokKind::Ident
        {
            // Path call `Type::name(`.
            let base = &toks[i - 3].text;
            if base == "self" || base == "Self" {
                recv = f.impl_type.clone();
            } else {
                recv = Some(base.clone());
            }
        }
        out.push(CallSite { callee: name, recv_type: recv, line: toks[i].line });
    }
    out
}

/// Candidate node indices for one call site (see module docs for the
/// three precision rules). Test fns are never targets.
fn resolve(
    site: &CallSite,
    nodes: &[FnNode],
    by_name: &HashMap<String, Vec<usize>>,
) -> Vec<usize> {
    let cands: Vec<usize> = by_name
        .get(&site.callee)
        .map(|v| v.iter().copied().filter(|&ix| !nodes[ix].is_test).collect())
        .unwrap_or_default();
    if cands.is_empty() {
        return Vec::new();
    }
    if let Some(recv) = &site.recv_type {
        let typed: Vec<usize> = cands
            .iter()
            .copied()
            .filter(|&ix| nodes[ix].impl_type.as_deref() == Some(recv.as_str()))
            .collect();
        if typed.iter().any(|&ix| nodes[ix].has_body) {
            return typed;
        }
        if typed.is_empty() {
            // Receiver type is known and no impl exists in the crate:
            // std/extern type, not ours.
            return Vec::new();
        }
        // Typed but bodiless trait declarations only: dyn dispatch.
        return cands;
    }
    if UNTYPED_SKIP.contains(&site.callee.as_str()) {
        return Vec::new();
    }
    cands
}

impl CallGraph {
    /// Build the graph for a model set. One pass per file for node
    /// collection, one for call extraction + resolution.
    pub fn build(models: &[FileModel]) -> CallGraph {
        let mut nodes = Vec::new();
        let mut index = HashMap::new();
        let mut by_name: HashMap<String, Vec<usize>> = HashMap::new();
        for (fi, m) in models.iter().enumerate() {
            for (xi, f) in m.fns.iter().enumerate() {
                let ix = nodes.len();
                nodes.push(FnNode {
                    file_ix: fi,
                    fn_ix: xi,
                    name: f.name.clone(),
                    line: f.line,
                    is_test: f.is_test || m.file_is_test,
                    is_hot: f.is_hot,
                    has_body: !f.body.is_empty(),
                    impl_type: f.impl_type.clone(),
                    resolved_sites: Vec::new(),
                    resolved: Vec::new(),
                });
                index.insert((fi, xi), ix);
                by_name.entry(f.name.clone()).or_default().push(ix);
            }
        }
        let fields = field_types(models);
        for ix in 0..nodes.len() {
            let (fi, xi) = (nodes[ix].file_ix, nodes[ix].fn_ix);
            let m = &models[fi];
            let f = &m.fns[xi];
            if f.body.is_empty() {
                continue;
            }
            let mut sites = Vec::new();
            let mut all: Vec<usize> = Vec::new();
            for c in extract_calls(m, f, &fields) {
                let targets = resolve(&c, &nodes, &by_name);
                all.extend(targets.iter().copied());
                sites.push(ResolvedSite { callee: c.callee, line: c.line, targets });
            }
            all.sort_unstable();
            all.dedup();
            nodes[ix].resolved_sites = sites;
            nodes[ix].resolved = all;
        }
        let mut rev: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
        for (ix, n) in nodes.iter().enumerate() {
            for &t in &n.resolved {
                rev[t].push(ix);
            }
        }
        CallGraph { nodes, index, rev }
    }

    /// Node index of `models[file_ix].fns[fn_ix]`.
    pub fn node_of(&self, file_ix: usize, fn_ix: usize) -> Option<usize> {
        self.index.get(&(file_ix, fn_ix)).copied()
    }

    /// Total resolved edge count (metrics / the CI artifact).
    pub fn n_edges(&self) -> usize {
        self.nodes.iter().map(|n| n.resolved.len()).sum()
    }

    /// Backward obligation propagation: starting from `seed` (per-node
    /// dirtiness), mark every node any of whose resolved callees is
    /// dirty, to fixpoint. This is the engine behind panic-path and
    /// hot-path-reach.
    pub fn propagate(&self, mut dirty: Vec<bool>) -> Vec<bool> {
        let mut changed = true;
        while changed {
            changed = false;
            for (ix, n) in self.nodes.iter().enumerate() {
                if dirty[ix] {
                    continue;
                }
                if n.resolved.iter().any(|&t| dirty[t]) {
                    dirty[ix] = true;
                    changed = true;
                }
            }
        }
        dirty
    }

    /// Forward closure: every node reachable from `start` through
    /// resolved edges (excluding `start` itself unless cyclic).
    pub fn reachable(&self, start: usize) -> Vec<bool> {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack: Vec<usize> = self.nodes[start].resolved.clone();
        while let Some(ix) = stack.pop() {
            if seen[ix] {
                continue;
            }
            seen[ix] = true;
            stack.extend(self.nodes[ix].resolved.iter().copied());
        }
        seen
    }

    /// Reverse-transitive closure: every node that can reach `target`.
    pub fn callers_of(&self, target: usize) -> HashSet<usize> {
        let mut seen = HashSet::new();
        let mut stack = vec![target];
        while let Some(ix) = stack.pop() {
            for &c in &self.rev[ix] {
                if seen.insert(c) {
                    stack.push(c);
                }
            }
        }
        seen
    }

    /// JSON dump of the whole graph (the CI artifact): nodes with
    /// file/line/impl metadata and adjacency by node id. Names are
    /// Rust identifiers and repo paths — no escaping needed beyond
    /// what they cannot contain.
    pub fn dump_json(&self, models: &[FileModel]) -> String {
        let mut s = String::with_capacity(self.nodes.len() * 96);
        s.push_str("{\n  \"nodes\": [\n");
        for (ix, n) in self.nodes.iter().enumerate() {
            let path = &models[n.file_ix].path;
            s.push_str(&format!(
                "    {{\"id\": {}, \"fn\": \"{}\", \"file\": \"{}\", \"line\": {}, \
                 \"impl\": {}, \"test\": {}, \"hot\": {}, \"calls\": [{}]}}{}\n",
                ix,
                n.name,
                path,
                n.line,
                match &n.impl_type {
                    Some(t) => format!("\"{t}\""),
                    None => "null".to_string(),
                },
                n.is_test,
                n.is_hot,
                n.resolved.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(", "),
                if ix + 1 < self.nodes.len() { "," } else { "" },
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!("  \"n_fns\": {},\n", self.nodes.len()));
        s.push_str(&format!("  \"n_files\": {},\n", models.len()));
        s.push_str(&format!("  \"n_edges\": {}\n}}\n", self.n_edges()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(files: &[(&str, &str)]) -> (Vec<FileModel>, CallGraph) {
        let models: Vec<FileModel> =
            files.iter().map(|(p, s)| FileModel::build(p, s)).collect();
        let g = CallGraph::build(&models);
        (models, g)
    }

    fn node<'g>(g: &'g CallGraph, name: &str) -> &'g FnNode {
        g.nodes.iter().find(|n| n.name == name).unwrap()
    }

    #[test]
    fn free_call_resolves_by_name() {
        let (_, g) = graph(&[
            ("src/a.rs", "pub fn caller() { helper(1); }"),
            ("src/b.rs", "pub fn helper(x: u32) -> u32 { x }"),
        ]);
        let c = node(&g, "caller");
        let h = g.nodes.iter().position(|n| n.name == "helper").unwrap();
        assert_eq!(c.resolved, vec![h]);
    }

    #[test]
    fn typed_receiver_narrows_and_std_types_drop() {
        let src_a = "\
pub fn run(s: &Store, m: &std::collections::HashMap<K, V>) {
    s.get(1);
    m.get(&k);
}
";
        let src_b = "\
pub struct Store { xs: Vec<u32> }
impl Store { pub fn get(&self, i: usize) -> u32 { 0 } }
pub struct Other;
impl Other { pub fn get(&self) -> u32 { 1 } }
";
        let (_, g) = graph(&[("src/a.rs", src_a), ("src/b.rs", src_b)]);
        let run = node(&g, "run");
        // `s.get` resolves to Store::get only; `m.get` (HashMap — no
        // crate impl) resolves to nothing.
        assert_eq!(run.resolved.len(), 1);
        let t = run.resolved[0];
        assert_eq!(g.nodes[t].impl_type.as_deref(), Some("Store"));
    }

    #[test]
    fn untyped_prelude_name_produces_no_edges() {
        let (_, g) = graph(&[
            ("src/a.rs", "pub fn run(x: &X) { let v = something(); v.get(0); }"),
            ("src/b.rs", "pub struct S; impl S { pub fn get(&self) -> u32 { 0 } }"),
        ]);
        // `v` has unknown type and `get` collides with the prelude.
        assert!(node(&g, "run").resolved.is_empty());
    }

    #[test]
    fn dyn_trait_call_fans_out_to_impls() {
        let files = [
            (
                "src/t.rs",
                "pub trait Backend { fn step(&mut self); }",
            ),
            (
                "src/a.rs",
                "pub struct A; impl Backend for A { fn step(&mut self) { a_work(); } }\nfn a_work() {}",
            ),
            (
                "src/b.rs",
                "pub struct B; impl Backend for B { fn step(&mut self) { b_work(); } }\nfn b_work() {}",
            ),
            ("src/run.rs", "pub fn drive(b: &mut dyn Backend) { b.step(); }"),
        ];
        let (_, g) = graph(&files);
        let drive = node(&g, "drive");
        // Resolves through the bodiless trait decl to both impls (and
        // the decl itself — harmless, it has no body to propagate).
        let impls: Vec<&str> = drive
            .resolved
            .iter()
            .filter(|&&t| g.nodes[t].has_body)
            .map(|&t| g.nodes[t].impl_type.as_deref().unwrap())
            .collect();
        assert!(impls.contains(&"A") && impls.contains(&"B"), "{impls:?}");
    }

    #[test]
    fn field_map_types_method_chains() {
        let files = [
            (
                "src/a.rs",
                "pub struct Engine { pool: ThreadPool }\nimpl Engine { pub fn go(&self) { self.pool.submit(j); } }",
            ),
            (
                "src/b.rs",
                "pub struct ThreadPool;\nimpl ThreadPool { pub fn submit(&self, j: J) {} }",
            ),
        ];
        let (_, g) = graph(&files);
        let go = node(&g, "go");
        assert_eq!(go.resolved.len(), 1);
        assert_eq!(g.nodes[go.resolved[0]].name, "submit");
    }

    #[test]
    fn propagation_and_callers() {
        let (_, g) = graph(&[
            ("src/a.rs", "pub fn top() { mid(); }"),
            ("src/b.rs", "pub fn mid() { deep(); }"),
            ("src/c.rs", "pub fn deep() {}"),
        ]);
        let deep = g.nodes.iter().position(|n| n.name == "deep").unwrap();
        let top = g.nodes.iter().position(|n| n.name == "top").unwrap();
        let mut seed = vec![false; g.nodes.len()];
        seed[deep] = true;
        let dirty = g.propagate(seed);
        assert!(dirty[top], "dirtiness propagates to transitive callers");
        assert!(g.callers_of(deep).contains(&top));
        assert!(g.reachable(top)[deep]);
    }

    #[test]
    fn test_fns_are_never_targets() {
        let (_, g) = graph(&[
            ("src/a.rs", "pub fn caller() { helper(); }"),
            ("src/b.rs", "#[cfg(test)]\nmod t { pub fn helper() {} }"),
        ]);
        assert!(node(&g, "caller").resolved.is_empty());
    }

    #[test]
    fn dump_json_mentions_every_fn() {
        let (models, g) = graph(&[
            ("src/a.rs", "pub fn caller() { helper(); }"),
            ("src/b.rs", "pub fn helper() {}"),
        ]);
        let dump = g.dump_json(&models);
        assert!(dump.contains("\"fn\": \"caller\""), "{dump}");
        assert!(dump.contains("\"n_edges\": 1"), "{dump}");
    }
}
