//! `sparselint`: repo-invariant static analysis.
//!
//! A zero-dependency, token-level linter for the cross-cutting
//! contracts the runtime tests cannot own per-file. v2 builds a
//! crate-wide program model — every file's `FileModel` plus a
//! heuristic [`callgraph::CallGraph`] over all of them — and checks:
//!
//! - **txn-pairing**: begin must reach commit/rollback on every path;
//!   split-phase sessions are resolved through the call graph (some
//!   caller chain must reach both settles), not a same-file guess.
//! - **pin-conservation**: pins settle in-function, in a tracker, or
//!   in a callee reachable through the graph (cross-file delegation).
//! - **no-panic** / **panic-path**: direct panics on serving paths,
//!   plus interprocedural reachability — a serving fn is flagged when
//!   any callee transitively reaches an unjustified `.unwrap()`.
//! - **hot-path** / **hot-path-reach**: the zero-alloc contract from
//!   PR 4, direct sites and through helpers.
//! - **step-typestate**: linear begin → stage → prefill/decode* →
//!   commit|rollback order over the StepSession protocol.
//! - **unit-dim**: suffix-convention dimensional analysis over the
//!   cost model (`_s`, `_us`, `_bytes`, `_blocks`, `_per_s`; knows
//!   `bytes / bytes_per_s = s` and `* 1e6` / `secs_to_us` as the only
//!   s→us conversions).
//! - **dead-knob** / **dead-counter** liveness (the `compute_s`
//!   lesson from PR 6).
//!
//! Driven by the `sparselint` binary (`cargo run --release --bin
//! sparselint`), configured by the checked-in `rust/lint.toml`,
//! suppressed site-by-site with `// sparselint: allow(<pass>) --
//! <reason>` comments. Design rationale (why tokens, not an AST; why
//! a heuristic call graph is enough) lives in DESIGN.md.

pub mod callgraph;
pub mod config;
pub mod lexer;
pub mod model;
pub mod passes;

use std::time::Instant;

pub use callgraph::CallGraph;
pub use config::Config;
pub use model::FileModel;

/// One finding: `file:line: [pass] msg`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub pass: String,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.pass, self.msg)
    }
}

/// A file handed to the analyzer: repo-relative path + contents.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub src: String,
}

/// Per-pass accounting for the CI stats artifact.
#[derive(Debug, Clone)]
pub struct PassStat {
    pub name: String,
    /// Findings before suppression.
    pub raw: usize,
    /// Findings surviving allow comments / allowlist entries.
    pub kept: usize,
    /// Wall-clock of the pass body (excludes model/graph build).
    pub micros: u128,
}

/// Full analysis result: diagnostics plus the program-model shape and
/// per-pass stats the CI job uploads.
#[derive(Debug)]
pub struct Analysis {
    pub diags: Vec<Diagnostic>,
    pub stats: Vec<PassStat>,
    pub n_files: usize,
    pub n_fns: usize,
    pub n_edges: usize,
}

/// Run every armed pass over `files` under `cfg`, apply allow-comment
/// and allowlist suppression, and return diagnostics sorted by (file,
/// line) plus per-pass stats. `only` restricts to a single pass by
/// name (the `--pass` flag). The four v2 passes arm themselves on
/// their config tables; the v1 passes always run, so a config without
/// the new tables behaves exactly as before. Allow-grammar findings
/// are never suppressible.
pub fn analyze_with(files: &[SourceFile], cfg: &Config, only: Option<&str>) -> Analysis {
    let models: Vec<FileModel> =
        files.iter().map(|f| FileModel::build(&f.path, &f.src)).collect();
    let graph = CallGraph::build(&models);
    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut stats: Vec<PassStat> = Vec::new();

    let mut run = |name: &str, body: &mut dyn FnMut(&mut Vec<Diagnostic>)| {
        if only.map(|o| o != name).unwrap_or(false) {
            return;
        }
        let t0 = Instant::now();
        let mut raw: Vec<Diagnostic> = Vec::new();
        body(&mut raw);
        let n_raw = raw.len();
        let kept: Vec<Diagnostic> = if name == passes::PASS_ALLOW_GRAMMAR {
            raw // meta-pass: unsuppressible
        } else {
            raw.into_iter().filter(|d| !suppressed(d, &models, cfg)).collect()
        };
        stats.push(PassStat {
            name: name.to_string(),
            raw: n_raw,
            kept: kept.len(),
            micros: t0.elapsed().as_micros(),
        });
        diags.extend(kept);
    };

    run(passes::PASS_TXN, &mut |out| passes::txn_pairing(&models, &graph, cfg, out));
    run(passes::PASS_PINS, &mut |out| passes::pin_conservation(&models, &graph, cfg, out));
    run(passes::PASS_NO_PANIC, &mut |out| passes::no_panic(&models, cfg, out));
    run(passes::PASS_HOT, &mut |out| passes::hot_path(&models, cfg, out));
    run(passes::PASS_PANIC_PATH, &mut |out| passes::panic_path(&models, &graph, cfg, out));
    run(passes::PASS_HOT_REACH, &mut |out| passes::hot_path_reach(&models, &graph, cfg, out));
    run(passes::PASS_STEP, &mut |out| passes::step_typestate(&models, cfg, out));
    run(passes::PASS_UNIT, &mut |out| passes::unit_dim(&models, cfg, out));
    run(passes::PASS_DEAD_KNOB, &mut |out| passes::dead_knob(&models, cfg, out));
    run(passes::PASS_DEAD_COUNTER, &mut |out| passes::dead_counter(&models, cfg, out));
    run(passes::PASS_ALLOW_GRAMMAR, &mut |out| passes::allow_grammar(&models, out));

    diags.sort_by(|a, b| (&a.file, a.line, &a.pass).cmp(&(&b.file, b.line, &b.pass)));
    Analysis {
        diags,
        stats,
        n_files: models.len(),
        n_fns: graph.nodes.len(),
        n_edges: graph.n_edges(),
    }
}

/// Back-compat entry: all passes, diagnostics only.
pub fn analyze(files: &[SourceFile], cfg: &Config) -> Vec<Diagnostic> {
    analyze_with(files, cfg, None).diags
}

/// Build the crate-wide call graph and dump it as JSON (the
/// `--emit-callgraph` CI artifact).
pub fn emit_callgraph(files: &[SourceFile]) -> String {
    let models: Vec<FileModel> =
        files.iter().map(|f| FileModel::build(&f.path, &f.src)).collect();
    CallGraph::build(&models).dump_json(&models)
}

/// A diagnostic is suppressed by a well-formed allow comment for the
/// same pass whose target line matches, or by a `[[allow]]` config
/// entry matching (pass, file[, line]).
fn suppressed(d: &Diagnostic, models: &[FileModel], cfg: &Config) -> bool {
    if let Some(m) = models.iter().find(|m| m.path == d.file) {
        let by_comment = m.allows.iter().any(|a| {
            a.malformed.is_none()
                && a.pass == d.pass
                && (a.applies_to == d.line || a.line == d.line)
        });
        if by_comment {
            return true;
        }
    }
    cfg.allows.iter().any(|a| {
        a.pass == d.pass
            && d.file.ends_with(&a.file)
            && a.line.map(|l| l == d.line).unwrap_or(true)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(path: &str, src: &str) -> Vec<SourceFile> {
        vec![SourceFile { path: path.into(), src: src.into() }]
    }

    fn cfg_no_panic() -> Config {
        Config::from_toml("[no_panic]\nmodules = [\"engine\"]\n").unwrap()
    }

    #[test]
    fn no_panic_fires_and_allow_comment_suppresses() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let d = analyze(&one("src/engine/core.rs", bad), &cfg_no_panic());
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].pass, "no-panic");

        let allowed = "// sparselint: allow(no-panic) -- proven nonempty by caller\nfn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let d = analyze(&one("src/engine/core.rs", allowed), &cfg_no_panic());
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn bare_allow_is_reported_and_does_not_suppress() {
        let src = "// sparselint: allow(no-panic)\nfn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let d = analyze(&one("src/engine/core.rs", src), &cfg_no_panic());
        let passes: Vec<&str> = d.iter().map(|x| x.pass.as_str()).collect();
        assert!(passes.contains(&"no-panic"), "{d:?}");
        assert!(passes.contains(&"allow-grammar"), "{d:?}");
    }

    #[test]
    fn config_allowlist_suppresses() {
        let toml = "[no_panic]\nmodules = [\"engine\"]\n\n[[allow]]\npass = \"no-panic\"\nfile = \"src/engine/core.rs\"\nreason = \"fixture\"\n";
        let cfg = Config::from_toml(toml).unwrap();
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let d = analyze(&one("src/engine/core.rs", bad), &cfg);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn diagnostics_sorted_and_display() {
        let src = "fn f(a: Vec<u32>) -> u32 { a[0] + a.clone()[1] }";
        let d = analyze(&one("src/engine/x.rs", src), &cfg_no_panic());
        assert!(!d.is_empty());
        let s = d[0].to_string();
        assert!(s.starts_with("src/engine/x.rs:1: [no-panic]"), "{s}");
    }

    #[test]
    fn pass_filter_restricts_and_stats_cover_armed_passes() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let files = one("src/engine/core.rs", src);
        let cfg = cfg_no_panic();
        let all = analyze_with(&files, &cfg, None);
        assert!(all.stats.iter().any(|s| s.name == "no-panic" && s.kept == 1));
        assert!(all.n_fns >= 1 && all.n_files == 1);
        let only = analyze_with(&files, &cfg, Some("txn-pairing"));
        assert!(only.diags.is_empty(), "{:?}", only.diags);
        assert_eq!(only.stats.len(), 1);
        assert_eq!(only.stats[0].name, "txn-pairing");
    }

    #[test]
    fn emit_callgraph_names_fns_and_edges() {
        let files = vec![
            SourceFile {
                path: "src/a.rs".into(),
                src: "fn outer() { helper(); }\nfn helper() {}\n".into(),
            },
        ];
        let js = emit_callgraph(&files);
        assert!(js.contains("\"outer\""), "{js}");
        assert!(js.contains("\"helper\""), "{js}");
        assert!(js.contains("\"n_edges\""), "{js}");
    }
}
