//! `sparselint`: repo-invariant static analysis.
//!
//! A zero-dependency, token-level linter for the cross-cutting
//! contracts the runtime tests cannot own per-file: txn pairing
//! (begin must reach commit/rollback on every path), pin conservation
//! across aborts, the no-panic serving-path contract, the zero-alloc
//! hot-path contract from PR 4, and dead-knob/dead-counter liveness
//! (the `compute_s` lesson from PR 6). Driven by the `sparselint`
//! binary (`cargo run --release --bin sparselint`), configured by the
//! checked-in `rust/lint.toml`, suppressed site-by-site with
//! `// sparselint: allow(<pass>) -- <reason>` comments.
//!
//! Design rationale (why tokens, not an AST) lives in DESIGN.md.

pub mod config;
pub mod lexer;
pub mod model;
pub mod passes;

pub use config::Config;
pub use model::FileModel;

/// One finding: `file:line: [pass] msg`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub pass: String,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.pass, self.msg)
    }
}

/// A file handed to the analyzer: repo-relative path + contents.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub src: String,
}

/// Run every pass over `files` under `cfg`, apply allow-comment and
/// allowlist suppression, and return the surviving diagnostics sorted
/// by (file, line). Allow-grammar findings are never suppressible.
pub fn analyze(files: &[SourceFile], cfg: &Config) -> Vec<Diagnostic> {
    let models: Vec<FileModel> =
        files.iter().map(|f| FileModel::build(&f.path, &f.src)).collect();
    let mut raw = Vec::new();
    passes::txn_pairing(&models, cfg, &mut raw);
    passes::pin_conservation(&models, cfg, &mut raw);
    passes::no_panic(&models, cfg, &mut raw);
    passes::hot_path(&models, cfg, &mut raw);
    passes::dead_knob(&models, cfg, &mut raw);
    passes::dead_counter(&models, cfg, &mut raw);
    let mut kept: Vec<Diagnostic> =
        raw.into_iter().filter(|d| !suppressed(d, &models, cfg)).collect();
    passes::allow_grammar(&models, &mut kept);
    kept.sort_by(|a, b| (&a.file, a.line, &a.pass).cmp(&(&b.file, b.line, &b.pass)));
    kept
}

/// A diagnostic is suppressed by a well-formed allow comment for the
/// same pass whose target line matches, or by a `[[allow]]` config
/// entry matching (pass, file[, line]).
fn suppressed(d: &Diagnostic, models: &[FileModel], cfg: &Config) -> bool {
    if let Some(m) = models.iter().find(|m| m.path == d.file) {
        let by_comment = m.allows.iter().any(|a| {
            a.malformed.is_none()
                && a.pass == d.pass
                && (a.applies_to == d.line || a.line == d.line)
        });
        if by_comment {
            return true;
        }
    }
    cfg.allows.iter().any(|a| {
        a.pass == d.pass
            && d.file.ends_with(&a.file)
            && a.line.map(|l| l == d.line).unwrap_or(true)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(path: &str, src: &str) -> Vec<SourceFile> {
        vec![SourceFile { path: path.into(), src: src.into() }]
    }

    fn cfg_no_panic() -> Config {
        Config::from_toml("[no_panic]\nmodules = [\"engine\"]\n").unwrap()
    }

    #[test]
    fn no_panic_fires_and_allow_comment_suppresses() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let d = analyze(&one("src/engine/core.rs", bad), &cfg_no_panic());
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].pass, "no-panic");

        let allowed = "// sparselint: allow(no-panic) -- proven nonempty by caller\nfn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let d = analyze(&one("src/engine/core.rs", allowed), &cfg_no_panic());
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn bare_allow_is_reported_and_does_not_suppress() {
        let src = "// sparselint: allow(no-panic)\nfn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let d = analyze(&one("src/engine/core.rs", src), &cfg_no_panic());
        let passes: Vec<&str> = d.iter().map(|x| x.pass.as_str()).collect();
        assert!(passes.contains(&"no-panic"), "{d:?}");
        assert!(passes.contains(&"allow-grammar"), "{d:?}");
    }

    #[test]
    fn config_allowlist_suppresses() {
        let toml = "[no_panic]\nmodules = [\"engine\"]\n\n[[allow]]\npass = \"no-panic\"\nfile = \"src/engine/core.rs\"\nreason = \"fixture\"\n";
        let cfg = Config::from_toml(toml).unwrap();
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let d = analyze(&one("src/engine/core.rs", bad), &cfg);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn diagnostics_sorted_and_display() {
        let src = "fn f(a: Vec<u32>) -> u32 { a[0] + a.clone()[1] }";
        let d = analyze(&one("src/engine/x.rs", src), &cfg_no_panic());
        assert!(!d.is_empty());
        let s = d[0].to_string();
        assert!(s.starts_with("src/engine/x.rs:1: [no-panic]"), "{s}");
    }
}
