//! `lint.toml` loading: a deliberately tiny TOML subset parser (no
//! external crates) plus the typed `Config` the passes consume.
//!
//! Supported TOML subset: `[section]`, `[[array.of.tables]]`,
//! `key = "string" | 123 | true | ["a", "b", ...]` (arrays of strings
//! only, single- or multi-line), `#` comments. That is everything the
//! checked-in `rust/lint.toml` needs; anything fancier is a config
//! error, not a silent skip.

/// One `key = value` entry.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlVal {
    Str(String),
    Int(i64),
    Bool(bool),
    Arr(Vec<String>),
}

/// One `[name]` or `[[name]]` table, entries in file order.
#[derive(Debug, Clone)]
pub struct TomlTable {
    pub name: String,
    pub entries: Vec<(String, TomlVal)>,
}

/// Parse the TOML subset. Returns tables in order; repeated `[[x]]`
/// headers produce one table each.
pub fn parse_toml(src: &str) -> Result<Vec<TomlTable>, String> {
    let mut tables: Vec<TomlTable> = vec![TomlTable { name: String::new(), entries: Vec::new() }];
    let mut lines = src.lines().enumerate().peekable();
    while let Some((ln, raw)) = lines.next() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let lineno = ln + 1;
        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            tables.push(TomlTable { name: name.trim().to_string(), entries: Vec::new() });
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            tables.push(TomlTable { name: name.trim().to_string(), entries: Vec::new() });
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(format!("line {lineno}: expected `key = value`, got `{line}`"));
        };
        let key = line[..eq].trim().to_string();
        let mut val = line[eq + 1..].trim().to_string();
        // Multi-line array: keep consuming lines until brackets close.
        if val.starts_with('[') {
            while !array_closed(&val) {
                let Some((_, next)) = lines.next() else {
                    return Err(format!("line {lineno}: unterminated array for `{key}`"));
                };
                val.push(' ');
                val.push_str(strip_comment(next).trim());
            }
        }
        let parsed = parse_value(&val).map_err(|e| format!("line {lineno}: {e}"))?;
        let Some(tbl) = tables.last_mut() else {
            return Err(format!("line {lineno}: entry before any table"));
        };
        tbl.entries.push((key, parsed));
    }
    Ok(tables)
}

/// `#` starts a comment unless inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn array_closed(val: &str) -> bool {
    let mut depth = 0isize;
    let mut in_str = false;
    for c in val.chars() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            _ => {}
        }
    }
    depth == 0
}

fn parse_value(v: &str) -> Result<TomlVal, String> {
    let v = v.trim();
    if let Some(s) = v.strip_prefix('"') {
        let Some(s) = s.strip_suffix('"') else {
            return Err(format!("unterminated string `{v}`"));
        };
        return Ok(TomlVal::Str(s.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if v == "true" {
        return Ok(TomlVal::Bool(true));
    }
    if v == "false" {
        return Ok(TomlVal::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            match parse_value(p)? {
                TomlVal::Str(s) => items.push(s),
                other => return Err(format!("arrays hold strings only, got {other:?}")),
            }
        }
        return Ok(TomlVal::Arr(items));
    }
    v.parse::<i64>().map(TomlVal::Int).map_err(|_| format!("unrecognized value `{v}`"))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

// ---------------------------------------------------------------------------
// Typed config
// ---------------------------------------------------------------------------

/// One begin/commit/rollback function-name triple for txn-pairing.
#[derive(Debug, Clone)]
pub struct TxnPair {
    pub begin: String,
    pub commit: String,
    pub rollback: String,
}

/// Pin-conservation scope: in `file`, every function calling an
/// `acquire` method must also call a `release` method, push into a
/// `tracker` collection, or hand off via a `delegate` registration.
#[derive(Debug, Clone)]
pub struct PinScope {
    pub file: String,
    pub acquire: Vec<String>,
    pub release: Vec<String>,
    pub trackers: Vec<String>,
    pub delegates: Vec<String>,
}

/// Pin-conservation definitions check: `file` must define all of
/// `must_define` (the drain-side API the scopes above delegate to).
#[derive(Debug, Clone)]
pub struct PinDefs {
    pub file: String,
    pub must_define: Vec<String>,
}

/// Struct-liveness targets for the dead-knob / dead-counter pass.
#[derive(Debug, Clone, Default)]
pub struct DeadKnobCfg {
    pub struct_file: String,
    pub struct_name: String,
    pub exclude_dir: String,
}

#[derive(Debug, Clone, Default)]
pub struct DeadCounterCfg {
    pub struct_file: String,
    pub struct_name: String,
    pub report_dirs: Vec<String>,
    pub report_fns: Vec<String>,
}

/// StepSession protocol names for the step-typestate pass. Armed by
/// the presence of a `[step_session]` table.
#[derive(Debug, Clone)]
pub struct StepSessionCfg {
    pub begin: String,
    pub stage: String,
    pub prefill: String,
    pub decode: String,
    pub commit: String,
    pub rollback: String,
}

/// Unit-dimension pass scope: `files` are path substrings selecting
/// the cost-model surface; `converter` is the sanctioned s→us helper
/// (`secs_to_us`). Armed by the presence of a `[units]` table.
#[derive(Debug, Clone)]
pub struct UnitsCfg {
    pub files: Vec<String>,
    pub converter: String,
}

/// File-level allowlist entry from `lint.toml` (`[[allow]]`). A
/// missing/empty `reason` is a config error: the acceptance bar is
/// zero bare allowlist entries.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub pass: String,
    pub file: String,
    pub line: Option<u32>,
    pub reason: String,
}

#[derive(Debug, Clone, Default)]
pub struct Config {
    pub no_panic_modules: Vec<String>,
    /// Primary driver name, used in diagnostics ("go through the
    /// driver"). Always the first entry of `txn_drivers`.
    pub txn_driver: String,
    /// Every sanctioned step driver (`[txn] drivers = [...]`, plus the
    /// back-compat singular `driver` key). The synchronous and
    /// pipelined executors are separate functions held to the same
    /// contract, so the pass accepts any of them as a begin_step
    /// caller or a delegation target.
    pub txn_drivers: Vec<String>,
    /// The phase-entry method only `txn_drivers` may call directly
    /// (`begin_step`): everyone else must go through a driver.
    pub txn_step_begin: String,
    pub txn_pairs: Vec<TxnPair>,
    pub pin_scopes: Vec<PinScope>,
    pub pin_defs: Vec<PinDefs>,
    pub hot_banned_methods: Vec<String>,
    pub hot_banned_ctors: Vec<String>,
    /// Modules whose non-test fns must not *reach* a panic through any
    /// resolved call chain (interprocedural no-panic). Empty = pass off.
    pub panic_path_modules: Vec<String>,
    /// Arm the interprocedural hot-path allocation pass.
    pub hot_reach: bool,
    pub step_session: Option<StepSessionCfg>,
    pub units: Option<UnitsCfg>,
    pub dead_knob: Option<DeadKnobCfg>,
    pub dead_counter: Option<DeadCounterCfg>,
    pub allows: Vec<AllowEntry>,
}

fn get_str(t: &TomlTable, key: &str) -> Result<String, String> {
    match t.entries.iter().find(|(k, _)| k == key) {
        Some((_, TomlVal::Str(s))) => Ok(s.clone()),
        Some(_) => Err(format!("[{}] `{key}` must be a string", t.name)),
        None => Err(format!("[{}] missing required key `{key}`", t.name)),
    }
}

fn get_str_opt(t: &TomlTable, key: &str) -> Option<String> {
    match t.entries.iter().find(|(k, _)| k == key) {
        Some((_, TomlVal::Str(s))) => Some(s.clone()),
        _ => None,
    }
}

fn get_int_opt(t: &TomlTable, key: &str) -> Option<i64> {
    match t.entries.iter().find(|(k, _)| k == key) {
        Some((_, TomlVal::Int(i))) => Some(*i),
        _ => None,
    }
}

fn get_bool_or(t: &TomlTable, key: &str, default: bool) -> bool {
    match t.entries.iter().find(|(k, _)| k == key) {
        Some((_, TomlVal::Bool(b))) => *b,
        _ => default,
    }
}

fn get_arr(t: &TomlTable, key: &str) -> Vec<String> {
    match t.entries.iter().find(|(k, _)| k == key) {
        Some((_, TomlVal::Arr(a))) => a.clone(),
        _ => Vec::new(),
    }
}

impl Config {
    /// Parse a full config from TOML text.
    pub fn from_toml(src: &str) -> Result<Config, String> {
        let tables = parse_toml(src)?;
        let mut cfg = Config::default();
        for t in &tables {
            match t.name.as_str() {
                "" => {}
                "no_panic" => cfg.no_panic_modules = get_arr(t, "modules"),
                "txn" => {
                    cfg.txn_driver = get_str_opt(t, "driver").unwrap_or_default();
                    cfg.txn_step_begin = get_str_opt(t, "step_begin").unwrap_or_default();
                    cfg.txn_drivers = get_arr(t, "drivers");
                    // back-compat: the singular `driver` key is the
                    // primary driver and always a member of the set
                    if !cfg.txn_driver.is_empty()
                        && !cfg.txn_drivers.iter().any(|d| d == &cfg.txn_driver)
                    {
                        cfg.txn_drivers.insert(0, cfg.txn_driver.clone());
                    }
                    if cfg.txn_driver.is_empty() {
                        cfg.txn_driver = cfg.txn_drivers.first().cloned().unwrap_or_default();
                    }
                }
                "txn.pair" => cfg.txn_pairs.push(TxnPair {
                    begin: get_str(t, "begin")?,
                    commit: get_str(t, "commit")?,
                    rollback: get_str(t, "rollback")?,
                }),
                "pins.scope" => cfg.pin_scopes.push(PinScope {
                    file: get_str(t, "file")?,
                    acquire: get_arr(t, "acquire"),
                    release: get_arr(t, "release"),
                    trackers: get_arr(t, "trackers"),
                    delegates: get_arr(t, "delegates"),
                }),
                "pins.defs" => cfg.pin_defs.push(PinDefs {
                    file: get_str(t, "file")?,
                    must_define: get_arr(t, "must_define"),
                }),
                "hot" => {
                    cfg.hot_banned_methods = get_arr(t, "banned_methods");
                    cfg.hot_banned_ctors = get_arr(t, "banned_ctors");
                }
                "panic_path" => cfg.panic_path_modules = get_arr(t, "modules"),
                "hot_reach" => cfg.hot_reach = get_bool_or(t, "enabled", true),
                "step_session" => {
                    cfg.step_session = Some(StepSessionCfg {
                        begin: get_str(t, "begin")?,
                        stage: get_str(t, "stage")?,
                        prefill: get_str(t, "prefill")?,
                        decode: get_str(t, "decode")?,
                        commit: get_str(t, "commit")?,
                        rollback: get_str(t, "rollback")?,
                    })
                }
                "units" => {
                    cfg.units = Some(UnitsCfg {
                        files: get_arr(t, "files"),
                        converter: get_str_opt(t, "converter")
                            .unwrap_or_else(|| "secs_to_us".to_string()),
                    })
                }
                "dead_knob" => {
                    cfg.dead_knob = Some(DeadKnobCfg {
                        struct_file: get_str(t, "struct_file")?,
                        struct_name: get_str(t, "struct_name")?,
                        exclude_dir: get_str(t, "exclude_dir")?,
                    })
                }
                "dead_counter" => {
                    cfg.dead_counter = Some(DeadCounterCfg {
                        struct_file: get_str(t, "struct_file")?,
                        struct_name: get_str(t, "struct_name")?,
                        report_dirs: get_arr(t, "report_dirs"),
                        report_fns: get_arr(t, "report_fns"),
                    })
                }
                "allow" => {
                    let entry = AllowEntry {
                        pass: get_str(t, "pass")?,
                        file: get_str(t, "file")?,
                        line: get_int_opt(t, "line").map(|i| i as u32),
                        reason: get_str_opt(t, "reason").unwrap_or_default(),
                    };
                    if entry.reason.trim().is_empty() {
                        return Err(format!(
                            "[[allow]] for pass `{}` on `{}` has no reason — every \
                             allowlist entry must carry a justification",
                            entry.pass, entry.file
                        ));
                    }
                    cfg.allows.push(entry);
                }
                other => return Err(format!("unknown config table `[{other}]`")),
            }
        }
        Ok(cfg)
    }

    /// The repo's checked-in configuration. `rust/lint.toml` is the
    /// single source of truth; it is embedded so library users (the
    /// test suite) and the binary agree even when cwd differs.
    pub fn repo_default() -> Config {
        match Config::from_toml(include_str!("../../lint.toml")) {
            Ok(c) => c,
            Err(e) => panic!("rust/lint.toml is invalid: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subset() {
        let src = r#"
# comment
[no_panic]
modules = ["engine", "scheduler"] # trailing

[txn]
driver = "drive_step"

[[txn.pair]]
begin = "begin_txn"
commit = "commit_txn"
rollback = "rollback_txn"

[hot]
banned_methods = [
    "clone",
    "to_vec",
]
banned_ctors = ["Vec"]
"#;
        let cfg = Config::from_toml(src).unwrap();
        assert_eq!(cfg.no_panic_modules, vec!["engine", "scheduler"]);
        assert_eq!(cfg.txn_driver, "drive_step");
        // the singular key alone still yields a one-element driver set
        assert_eq!(cfg.txn_drivers, vec!["drive_step"]);
        assert_eq!(cfg.txn_pairs.len(), 1);
        assert_eq!(cfg.txn_pairs[0].commit, "commit_txn");
        assert_eq!(cfg.hot_banned_methods, vec!["clone", "to_vec"]);
    }

    #[test]
    fn txn_drivers_array_parses_and_merges_the_singular_key() {
        let src = "\
[txn]
driver = \"drive_step\"
drivers = [\"drive_step\", \"drive_step_pipelined\"]
step_begin = \"begin_step\"
";
        let cfg = Config::from_toml(src).unwrap();
        assert_eq!(cfg.txn_driver, "drive_step");
        assert_eq!(cfg.txn_drivers, vec!["drive_step", "drive_step_pipelined"]);

        // drivers-only config: the first entry becomes the primary
        let src = "[txn]\ndrivers = [\"a\", \"b\"]\n";
        let cfg = Config::from_toml(src).unwrap();
        assert_eq!(cfg.txn_driver, "a");
        assert_eq!(cfg.txn_drivers, vec!["a", "b"]);

        // singular key absent from the array: merged in front
        let src = "[txn]\ndriver = \"c\"\ndrivers = [\"a\"]\n";
        let cfg = Config::from_toml(src).unwrap();
        assert_eq!(cfg.txn_drivers, vec!["c", "a"]);
    }

    #[test]
    fn allow_without_reason_is_config_error() {
        let src = "[[allow]]\npass = \"no-panic\"\nfile = \"src/x.rs\"\n";
        let err = Config::from_toml(src).unwrap_err();
        assert!(err.contains("no reason"), "{err}");
    }

    #[test]
    fn allow_with_reason_and_line() {
        let src = "[[allow]]\npass = \"no-panic\"\nfile = \"src/x.rs\"\nline = 7\nreason = \"why\"\n";
        let cfg = Config::from_toml(src).unwrap();
        assert_eq!(cfg.allows.len(), 1);
        assert_eq!(cfg.allows[0].line, Some(7));
    }

    #[test]
    fn unknown_table_rejected() {
        assert!(Config::from_toml("[wat]\nx = 1\n").is_err());
    }

    #[test]
    fn repo_default_parses() {
        let cfg = Config::repo_default();
        assert!(!cfg.no_panic_modules.is_empty());
        assert!(!cfg.txn_pairs.is_empty());
        // both the synchronous and the pipelined executor are sanctioned
        assert_eq!(cfg.txn_driver, "drive_step");
        assert!(
            cfg.txn_drivers.iter().any(|d| d == "drive_step_pipelined"),
            "pipelined driver missing: {:?}",
            cfg.txn_drivers
        );
        assert!(cfg.dead_knob.is_some());
        assert!(cfg.dead_counter.is_some());
        // v2: the interprocedural + typestate + dimension passes are
        // armed by the checked-in config.
        assert!(!cfg.panic_path_modules.is_empty());
        assert!(cfg.hot_reach);
        let ss = cfg.step_session.as_ref().expect("[step_session] armed");
        assert_eq!(ss.begin, "begin_step");
        let units = cfg.units.as_ref().expect("[units] armed");
        assert!(!units.files.is_empty());
        assert_eq!(units.converter, "secs_to_us");
        assert!(cfg.allows.is_empty(), "acceptance bar: zero [[allow]] entries");
    }

    #[test]
    fn step_session_and_units_tables_parse() {
        let src = r#"
[step_session]
begin = "begin_step"
stage = "stage"
prefill = "prefill_segment"
decode = "decode_layer"
commit = "commit"
rollback = "rollback"

[units]
files = ["src/sim/cost.rs"]

[hot_reach]
enabled = true
"#;
        let cfg = Config::from_toml(src).unwrap();
        assert_eq!(cfg.step_session.unwrap().decode, "decode_layer");
        let units = cfg.units.unwrap();
        assert_eq!(units.converter, "secs_to_us", "converter defaults");
        assert!(cfg.hot_reach);
    }
}
