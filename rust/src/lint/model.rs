//! Per-file structural model built from the token stream.
//!
//! `FileModel` slices a lexed file into functions (token ranges found
//! by brace matching), marks which token ranges are test code
//! (`#[cfg(test)]` / `#[test]` items), and parses the two comment
//! grammars the passes consume:
//!
//! * `// sparselint: allow(<pass>) -- <reason>` — suppress one pass on
//!   the same line or the line(s) immediately below the comment run.
//! * `// sparselint: hot` — marks the *next* function as a steady-state
//!   hot path; the clone-ban pass checks its whole body.

use super::lexer::{lex, Comment, Tok, TokKind};

/// One extracted function: `name` plus the token index range of its
/// body (exclusive of the outer braces) and the full item range
/// starting at the `fn` keyword.
#[derive(Debug, Clone)]
pub struct FnInfo {
    pub name: String,
    /// Index of the `fn` token.
    pub start: usize,
    /// Token range of the body, `{`-exclusive. Empty for bodiless
    /// trait-method declarations.
    pub body: std::ops::Range<usize>,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Inside `#[cfg(test)]` / under `#[test]`, or in a test/driver file.
    pub is_test: bool,
    /// Preceded by a `// sparselint: hot` marker.
    pub is_hot: bool,
    /// Self type of the innermost enclosing `impl`/`trait` block, if
    /// any (`impl Foo`, `impl Trait for Foo`, `trait Bar` all record
    /// the last path ident). The call graph uses this to type method
    /// receivers.
    pub impl_type: Option<String>,
    /// Exclusive end of the signature token range (the body `{`, or
    /// the `fn` token itself for bodiless declarations). The call
    /// graph scans `start..sig_end` for parameter types.
    pub sig_end: usize,
}

/// Parsed `// sparselint: allow(pass) -- reason` comment.
#[derive(Debug, Clone)]
pub struct AllowComment {
    /// Line the comment sits on.
    pub line: u32,
    /// First code line the allow applies to (the line below the
    /// comment run, or the comment's own line for trailing comments).
    pub applies_to: u32,
    pub pass: String,
    pub reason: String,
    /// Grammar violation detected while parsing (missing reason, ...).
    pub malformed: Option<String>,
}

#[derive(Debug)]
pub struct FileModel {
    /// Repo-relative path, `/`-separated.
    pub path: String,
    pub toks: Vec<Tok>,
    pub fns: Vec<FnInfo>,
    pub allows: Vec<AllowComment>,
    /// Whole file is test/driver code (tests/, benches/, examples/,
    /// src/bin/).
    pub file_is_test: bool,
}

impl FileModel {
    pub fn build(path: &str, src: &str) -> FileModel {
        let (toks, comments) = lex(src);
        let file_is_test = is_test_path(path);
        let (allows, hot_lines) = parse_markers(&comments, src);
        let test_spans = find_test_spans(&toks);
        let impl_spans = find_impl_spans(&toks);
        let fns = extract_fns(&toks, &test_spans, &hot_lines, &impl_spans, file_is_test);
        FileModel { path: path.to_string(), toks, fns, allows, file_is_test }
    }

    /// The function whose body contains token index `ti`, if any.
    /// Nested functions resolve to the innermost enclosing one.
    pub fn fn_at(&self, ti: usize) -> Option<&FnInfo> {
        self.fns
            .iter()
            .filter(|f| f.body.contains(&ti))
            .min_by_key(|f| f.body.end - f.body.start)
    }

    /// True if token index `ti` is inside test code.
    pub fn is_test_at(&self, ti: usize) -> bool {
        self.file_is_test || self.fn_at(ti).map(|f| f.is_test).unwrap_or(false)
    }
}

/// Files whose entire contents are test or driver code.
pub fn is_test_path(path: &str) -> bool {
    let p = path.replace('\\', "/");
    p.contains("/tests/")
        || p.starts_with("tests/")
        || p.contains("/benches/")
        || p.starts_with("benches/")
        || p.contains("/examples/")
        || p.starts_with("examples/")
        || p.contains("/src/bin/")
}

/// Scan comments for the two sparselint marker grammars. Returns the
/// parsed allow comments and the set of lines carrying a `hot` marker.
fn parse_markers(comments: &[Comment], src: &str) -> (Vec<AllowComment>, Vec<u32>) {
    // Record which lines contain any code (non-whitespace outside
    // comments is approximated by: line appears in a token). Cheaper:
    // map each comment line; `applies_to` is resolved against the raw
    // source below.
    let line_count = src.lines().count() as u32;
    let line_has_code: Vec<bool> = {
        let (toks, _) = lex(src);
        let mut v = vec![false; (line_count + 2) as usize];
        for t in &toks {
            // Multi-line tokens (strings) mark only their start line;
            // good enough — an allow comment never sits mid-string.
            if (t.line as usize) < v.len() {
                v[t.line as usize] = true;
            }
        }
        v
    };

    let mut allows = Vec::new();
    let mut hot_lines = Vec::new();
    for c in comments {
        let Some(rest) = strip_marker(&c.text) else { continue };
        if rest.trim() == "hot" {
            hot_lines.push(c.line);
            continue;
        }
        let applies_to = resolve_applies_to(c.line, &line_has_code, line_count);
        allows.push(parse_allow(rest, c.line, applies_to));
    }
    (allows, hot_lines)
}

/// Strip a leading `// sparselint:` (or `/* sparselint:`) header,
/// returning the remainder, or None for ordinary comments.
fn strip_marker(text: &str) -> Option<&str> {
    let t = text.trim_start_matches('/').trim_start_matches('*').trim_start();
    let rest = t.strip_prefix("sparselint")?;
    let rest = rest.trim_start().strip_prefix(':')?;
    Some(rest.trim())
}

/// An allow comment on its own line applies to the next line that has
/// code; a trailing comment applies to its own line. Comment runs
/// chain: each comment line counts as "no code", so a block of allow
/// comments above one statement all reach it.
fn resolve_applies_to(comment_line: u32, line_has_code: &[bool], line_count: u32) -> u32 {
    if line_has_code.get(comment_line as usize).copied().unwrap_or(false) {
        return comment_line; // trailing comment
    }
    let mut l = comment_line + 1;
    while l <= line_count {
        if line_has_code.get(l as usize).copied().unwrap_or(false) {
            return l;
        }
        l += 1;
    }
    comment_line
}

/// Parse `allow(<pass>) -- <reason>`; malformed variants are kept with
/// a description so the allow-grammar pass can report them.
fn parse_allow(rest: &str, line: u32, applies_to: u32) -> AllowComment {
    let mut out = AllowComment {
        line,
        applies_to,
        pass: String::new(),
        reason: String::new(),
        malformed: None,
    };
    let Some(body) = rest.strip_prefix("allow") else {
        out.malformed = Some(format!("unknown sparselint directive `{rest}`"));
        return out;
    };
    let body = body.trim_start();
    let Some(body) = body.strip_prefix('(') else {
        out.malformed = Some("expected `allow(<pass>)`".into());
        return out;
    };
    let Some(close) = body.find(')') else {
        out.malformed = Some("unclosed `allow(` — expected `allow(<pass>)`".into());
        return out;
    };
    out.pass = body[..close].trim().to_string();
    let tail = body[close + 1..].trim();
    match tail.strip_prefix("--") {
        Some(reason) if !reason.trim().is_empty() => out.reason = reason.trim().to_string(),
        _ => {
            out.malformed = Some(
                "allow comment missing justification: use `allow(<pass>) -- <reason>`".into(),
            );
        }
    }
    out
}

/// Token index ranges that belong to test code: a `#[cfg(test)]` or
/// `#[test]` attribute marks the following item (through its matching
/// closing brace or terminating `;`).
fn find_test_spans(toks: &[Tok]) -> Vec<std::ops::Range<usize>> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !toks[i].is_punct('#') {
            i += 1;
            continue;
        }
        // `#[ ... ]` — check for cfg(test) or test inside.
        let Some(open) = toks.get(i + 1).filter(|t| t.is_punct('[')) else {
            i += 1;
            continue;
        };
        let _ = open;
        let mut depth = 0usize;
        let mut j = i + 1;
        let mut is_test_attr = false;
        let mut saw_cfg = false;
        while j < toks.len() {
            let t = &toks[j];
            if t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(']') {
                depth -= 1;
                if depth == 0 {
                    j += 1;
                    break;
                }
            } else if t.is_ident("cfg") {
                saw_cfg = true;
            } else if t.is_ident("test") {
                // `#[test]`, `#[cfg(test)]`, `#[tokio::test]`-style
                is_test_attr = true;
            } else if t.is_ident("should_panic") {
                is_test_attr = true;
            }
            j += 1;
        }
        let _ = saw_cfg;
        if !is_test_attr {
            i = j;
            continue;
        }
        // Skip any further attributes, then consume the item.
        let mut k = j;
        while k < toks.len() && toks[k].is_punct('#') {
            let mut d = 0usize;
            k += 1;
            while k < toks.len() {
                if toks[k].is_punct('[') {
                    d += 1;
                } else if toks[k].is_punct(']') {
                    d -= 1;
                    if d == 0 {
                        k += 1;
                        break;
                    }
                }
                k += 1;
            }
        }
        // Item body: first `{` at brace depth 0 before a `;`.
        let start = i;
        let mut d = 0usize;
        let mut end = k;
        while end < toks.len() {
            let t = &toks[end];
            if t.is_punct('{') {
                d += 1;
            } else if t.is_punct('}') {
                d -= 1;
                if d == 0 {
                    end += 1;
                    break;
                }
            } else if t.is_punct(';') && d == 0 {
                end += 1;
                break;
            }
            end += 1;
        }
        spans.push(start..end);
        i = end;
    }
    spans
}

/// Body token ranges of every `impl`/`trait` block, with the self
/// type name: `impl Foo`, `impl Trait for Foo` and `trait Bar` record
/// `Foo`/`Foo`/`Bar` (last ident of the path after `for` when
/// present, generics skipped by angle-depth tracking). Fns inside
/// these spans get the name as their `impl_type`.
fn find_impl_spans(toks: &[Tok]) -> Vec<(std::ops::Range<usize>, Option<String>)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        if !(t.is_ident("impl") || t.is_ident("trait")) {
            i += 1;
            continue;
        }
        let is_trait = t.is_ident("trait");
        // Walk the header to its `{` (skip parenthesized/bracketed
        // groups; a `;` first means no body — bail).
        let mut j = i + 1;
        let mut depth_p = 0isize;
        let mut header: Vec<usize> = Vec::new();
        let mut found = false;
        while j < toks.len() {
            let tj = &toks[j];
            if tj.is_punct('(') || tj.is_punct('[') {
                depth_p += 1;
            } else if tj.is_punct(')') || tj.is_punct(']') {
                depth_p -= 1;
            } else if tj.is_punct('{') && depth_p == 0 {
                found = true;
                break;
            } else if tj.is_punct(';') && depth_p == 0 {
                break;
            }
            header.push(j);
            j += 1;
        }
        if !found {
            i = j + 1;
            continue;
        }
        // Self type: the path after `for` (impl Trait for Type), else
        // the whole header; within it, the last ident outside angle
        // brackets.
        let mut for_ix: Option<usize> = None;
        let mut angle = 0isize;
        for (k, &hi) in header.iter().enumerate() {
            let ht = &toks[hi];
            if ht.is_punct('<') {
                angle += 1;
            } else if ht.is_punct('>') {
                angle = (angle - 1).max(0);
            } else if angle == 0 && !is_trait && ht.is_ident("for") {
                for_ix = Some(k);
            }
        }
        let seg = match for_ix {
            Some(k) => &header[k + 1..],
            None => &header[..],
        };
        let mut name: Option<String> = None;
        let mut angle = 0isize;
        for &hi in seg {
            let ht = &toks[hi];
            if ht.is_punct('<') {
                angle += 1;
                continue;
            }
            if ht.is_punct('>') {
                angle = (angle - 1).max(0);
                continue;
            }
            if angle > 0 {
                continue;
            }
            if ht.is_ident("where") {
                break;
            }
            if ht.kind == TokKind::Ident && !ht.is_ident("mut") && !ht.is_ident("dyn") {
                name = Some(ht.text.clone());
            }
        }
        // Brace-match the body.
        let mut d = 1isize;
        let mut k = j + 1;
        while k < toks.len() && d > 0 {
            if toks[k].is_punct('{') {
                d += 1;
            } else if toks[k].is_punct('}') {
                d -= 1;
            }
            k += 1;
        }
        spans.push((j + 1..k.saturating_sub(1), name));
        // Continue just inside the body so nested impls are found too.
        i = j + 1;
    }
    spans
}

/// Extract all `fn` items (free functions, methods, nested fns) by
/// scanning for the `fn` keyword and brace-matching the body. The
/// signature is skipped with paren/bracket depth tracking; a `;`
/// before the body brace means a bodiless trait declaration.
fn extract_fns(
    toks: &[Tok],
    test_spans: &[std::ops::Range<usize>],
    hot_lines: &[u32],
    impl_spans: &[(std::ops::Range<usize>, Option<String>)],
    file_is_test: bool,
) -> Vec<FnInfo> {
    let in_test = |ti: usize| file_is_test || test_spans.iter().any(|s| s.contains(&ti));
    // Innermost enclosing impl/trait block wins (nested impls in fns).
    let impl_of = |ti: usize| -> Option<String> {
        impl_spans
            .iter()
            .filter(|(s, _)| s.contains(&ti))
            .min_by_key(|(s, _)| s.end - s.start)
            .and_then(|(_, n)| n.clone())
    };
    let mut fns = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].is_ident("fn")) {
            i += 1;
            continue;
        }
        let fn_ix = i;
        let Some(name_tok) = toks.get(i + 1) else { break };
        if name_tok.kind != TokKind::Ident {
            // `fn` inside a type position (`fn(...)` pointer) — skip.
            i += 1;
            continue;
        }
        let name = name_tok.text.clone();
        let line = toks[fn_ix].line;
        // A fn is hot if a `hot` marker sits within the 3 lines above
        // its `fn` keyword (attributes/doc lines may intervene).
        let is_hot =
            hot_lines.iter().any(|&hl| hl < line && line - hl <= 3) || hot_lines.contains(&line);
        // Walk the signature to the body `{` or a `;`.
        let mut j = i + 2;
        let mut paren = 0isize;
        let mut bracket = 0isize;
        let mut body = 0..0;
        let mut sig_end = fn_ix;
        while j < toks.len() {
            let t = &toks[j];
            if t.is_punct('(') {
                paren += 1;
            } else if t.is_punct(')') {
                paren -= 1;
            } else if t.is_punct('[') {
                bracket += 1;
            } else if t.is_punct(']') {
                bracket -= 1;
            } else if paren == 0 && bracket == 0 {
                if t.is_punct(';') {
                    // trait method declaration without body
                    j += 1;
                    break;
                }
                if t.is_punct('{') {
                    // brace-match the body
                    sig_end = j;
                    let body_start = j + 1;
                    let mut d = 1isize;
                    let mut k = body_start;
                    while k < toks.len() && d > 0 {
                        if toks[k].is_punct('{') {
                            d += 1;
                        } else if toks[k].is_punct('}') {
                            d -= 1;
                        }
                        k += 1;
                    }
                    body = body_start..k.saturating_sub(1);
                    j = k;
                    break;
                }
            }
            j += 1;
        }
        fns.push(FnInfo {
            name,
            start: fn_ix,
            body,
            line,
            is_test: in_test(fn_ix),
            is_hot,
            impl_type: impl_of(fn_ix),
            sig_end,
        });
        // Continue from just after the signature so nested fns inside
        // this body are also found.
        i = fn_ix + 2;
    }
    fns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_fns_and_bodies() {
        let m = FileModel::build(
            "src/x.rs",
            "fn a() { b(); }\nimpl T { fn c(&self) -> u32 { 1 } }\n",
        );
        let names: Vec<&str> = m.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["a", "c"]);
        assert!(!m.fns[0].body.is_empty());
    }

    #[test]
    fn nested_fns_found_and_innermost_wins() {
        let m = FileModel::build("src/x.rs", "fn outer() { fn inner() { q(); } inner(); }");
        assert_eq!(m.fns.len(), 2);
        let qi = m.toks.iter().position(|t| t.is_ident("q")).unwrap();
        assert_eq!(m.fn_at(qi).unwrap().name, "inner");
    }

    #[test]
    fn cfg_test_marks_module_fns() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests { fn helper() {} }\n";
        let m = FileModel::build("src/x.rs", src);
        let live = m.fns.iter().find(|f| f.name == "live").unwrap();
        let helper = m.fns.iter().find(|f| f.name == "helper").unwrap();
        assert!(!live.is_test);
        assert!(helper.is_test);
    }

    #[test]
    fn test_attr_marks_single_fn() {
        let src = "#[test]\nfn t() {}\nfn live() {}\n";
        let m = FileModel::build("src/x.rs", src);
        assert!(m.fns.iter().find(|f| f.name == "t").unwrap().is_test);
        assert!(!m.fns.iter().find(|f| f.name == "live").unwrap().is_test);
    }

    #[test]
    fn driver_paths_are_all_test() {
        for p in ["tests/a.rs", "rust/tests/a.rs", "examples/e.rs", "src/bin/b.rs", "benches/z.rs"]
        {
            assert!(is_test_path(p), "{p}");
        }
        assert!(!is_test_path("src/engine/core.rs"));
    }

    #[test]
    fn allow_comment_parses() {
        let src = "// sparselint: allow(no-panic) -- documented invariant\nlet x = y.unwrap();\n";
        let m = FileModel::build("src/x.rs", src);
        assert_eq!(m.allows.len(), 1);
        let a = &m.allows[0];
        assert_eq!(a.pass, "no-panic");
        assert_eq!(a.reason, "documented invariant");
        assert!(a.malformed.is_none());
        assert_eq!(a.applies_to, 2);
    }

    #[test]
    fn trailing_allow_applies_to_own_line() {
        let src = "let x = y.unwrap(); // sparselint: allow(no-panic) -- fine\n";
        let m = FileModel::build("src/x.rs", src);
        assert_eq!(m.allows[0].applies_to, 1);
    }

    #[test]
    fn bare_allow_is_malformed() {
        let src = "// sparselint: allow(no-panic)\nlet x = y.unwrap();\n";
        let m = FileModel::build("src/x.rs", src);
        assert!(m.allows[0].malformed.is_some());
    }

    #[test]
    fn hot_marker_tags_next_fn() {
        let src = "// sparselint: hot\nfn decode_inner() {}\nfn cold() {}\n";
        let m = FileModel::build("src/x.rs", src);
        assert!(m.fns.iter().find(|f| f.name == "decode_inner").unwrap().is_hot);
        assert!(!m.fns.iter().find(|f| f.name == "cold").unwrap().is_hot);
    }

    #[test]
    fn impl_type_resolves_for_inherent_trait_and_generic_blocks() {
        let src = "\
fn free() {}
impl Foo { fn a(&self) {} }
impl Display for Bar { fn fmt(&self) {} }
impl<'a, T: Clone> Iterator for Baz<'a, T> { fn next(&mut self) {} }
trait Backend { fn step(&mut self); fn with_default(&self) -> u32 { 0 } }
";
        let m = FileModel::build("src/x.rs", src);
        let ty = |name: &str| {
            m.fns.iter().find(|f| f.name == name).unwrap().impl_type.clone()
        };
        assert_eq!(ty("free"), None);
        assert_eq!(ty("a"), Some("Foo".into()));
        assert_eq!(ty("fmt"), Some("Bar".into()));
        assert_eq!(ty("next"), Some("Baz".into()));
        assert_eq!(ty("step"), Some("Backend".into()));
        assert_eq!(ty("with_default"), Some("Backend".into()));
        // bodiless trait declaration: empty body, sig intact
        let step = m.fns.iter().find(|f| f.name == "step").unwrap();
        assert!(step.body.is_empty());
        let with_default = m.fns.iter().find(|f| f.name == "with_default").unwrap();
        assert!(!with_default.body.is_empty());
        assert!(with_default.sig_end > with_default.start);
    }

    #[test]
    fn comment_run_chains_to_code_below() {
        let src = "// sparselint: allow(hot-path) -- amortized, grows once\n// more prose\nlet v = Vec::new();\n";
        let m = FileModel::build("src/x.rs", src);
        assert_eq!(m.allows[0].applies_to, 3);
    }
}
