//! The sparselint passes.
//!
//! Per-file passes walk token streams and the per-file function model;
//! the interprocedural passes (txn-pairing rule 2, pin delegation,
//! panic-path, hot-path-reach) additionally consult the crate-wide
//! [`CallGraph`]. No AST anywhere. Each diagnostic carries the pass
//! name so allow comments (`// sparselint: allow(<pass>) -- <reason>`)
//! and `[[allow]]` config entries can target it.

use std::collections::HashSet;

use super::callgraph::CallGraph;
use super::config::Config;
use super::lexer::{Tok, TokKind};
use super::model::FileModel;
use super::Diagnostic;

pub const PASS_TXN: &str = "txn-pairing";
pub const PASS_PINS: &str = "pin-conservation";
pub const PASS_NO_PANIC: &str = "no-panic";
pub const PASS_HOT: &str = "hot-path";
pub const PASS_PANIC_PATH: &str = "panic-path";
pub const PASS_HOT_REACH: &str = "hot-path-reach";
pub const PASS_STEP: &str = "step-typestate";
pub const PASS_UNIT: &str = "unit-dim";
pub const PASS_DEAD_KNOB: &str = "dead-knob";
pub const PASS_DEAD_COUNTER: &str = "dead-counter";
pub const PASS_ALLOW_GRAMMAR: &str = "allow-grammar";

/// Pass names an allow comment may reference.
pub const KNOWN_PASSES: &[&str] = &[
    PASS_TXN,
    PASS_PINS,
    PASS_NO_PANIC,
    PASS_HOT,
    PASS_PANIC_PATH,
    PASS_HOT_REACH,
    PASS_STEP,
    PASS_UNIT,
    PASS_DEAD_KNOB,
    PASS_DEAD_COUNTER,
];

fn diag(out: &mut Vec<Diagnostic>, pass: &str, file: &str, line: u32, msg: String) {
    out.push(Diagnostic { pass: pass.to_string(), file: file.to_string(), line, msg });
}

/// `toks[i]` is a *call* of `name`: ident with that text, followed by
/// `(`, not preceded by `fn` (definition). Method calls (`x.name(`)
/// and free calls both match.
fn is_call(toks: &[Tok], i: usize, name: &str) -> bool {
    if !toks[i].is_ident(name) {
        return false;
    }
    let called = toks.get(i + 1).map(|t| t.is_punct('(')).unwrap_or(false);
    let defined = i > 0 && toks[i - 1].is_ident("fn");
    called && !defined
}

/// Any call of `name` inside token range `r`.
fn range_has_call(toks: &[Tok], r: &std::ops::Range<usize>, name: &str) -> bool {
    r.clone().any(|i| is_call(toks, i, name))
}

/// First call of any of `names` inside `r`, by token index.
fn first_call(toks: &[Tok], r: &std::ops::Range<usize>, names: &[&str]) -> Option<usize> {
    r.clone().find(|&i| names.iter().any(|n| is_call(toks, i, n)))
}

/// A well-formed allow comment for any of `passes` whose target line
/// is `line`. The interprocedural passes consult this at direct sites
/// so a justified marker stops obligation propagation at its source
/// (the generic per-diagnostic suppression in `mod.rs` only covers the
/// *report* line, which for a propagated finding is a call site far
/// from the marker).
fn justified(m: &FileModel, line: u32, passes: &[&str]) -> bool {
    m.allows.iter().any(|a| {
        a.malformed.is_none()
            && passes.contains(&a.pass.as_str())
            && (a.applies_to == line || a.line == line)
    })
}

/// `path` is inside one of the configured `src/<module>` scopes.
fn in_module_scope(path: &str, modules: &[String]) -> bool {
    modules.iter().any(|md| {
        path.contains(&format!("src/{md}/")) || path.ends_with(&format!("src/{md}.rs"))
    })
}

/// Repo-relative display of a path (the `src/...` suffix).
fn short_path(p: &str) -> &str {
    match p.find("src/") {
        Some(i) => &p[i..],
        None => p,
    }
}

// ---------------------------------------------------------------------------
// Pass 1: txn-pairing
// ---------------------------------------------------------------------------

/// Two rules, applied to ALL code including tests (figures, benches
/// and tests drive backends directly and must uphold phase order):
///
/// 1. Only the configured drivers (`drive_step` and its pipelined
///    twin) may call the phase-entry method (`begin_step`) directly —
///    anything else is a hand-rolled phase order.
/// 2. For each begin/commit/rollback triple: a function calling
///    `begin` must either (a) contain `commit` or `rollback` with no
///    `?`/`return` escape between the begin and the first
///    commit/rollback, (b) delegate to the driver, or (c) be settled
///    by the call graph: some ancestor (a function that can reach this
///    one, or the function itself) must reach both a `commit` and a
///    `rollback` call site through resolved calls — the split-phase
///    session shape, now resolved across files instead of by the old
///    same-file heuristic.
pub fn txn_pairing(
    models: &[FileModel],
    graph: &CallGraph,
    cfg: &Config,
    out: &mut Vec<Diagnostic>,
) {
    // The sanctioned driver set: `[txn] drivers` when configured, else
    // the singular `driver` (configs built through `from_toml` always
    // populate the set; this fallback covers hand-built `Config`s).
    let mut drivers: Vec<&str> = cfg.txn_drivers.iter().map(|s| s.as_str()).collect();
    if drivers.is_empty() && !cfg.txn_driver.is_empty() {
        drivers.push(cfg.txn_driver.as_str());
    }
    // Rule 1: direct step_begin callers.
    if !cfg.txn_step_begin.is_empty() {
        for m in models {
            let toks = &m.toks;
            for f in &m.fns {
                if drivers.iter().any(|d| f.name == *d) {
                    continue;
                }
                for i in f.body.clone() {
                    if is_call(toks, i, &cfg.txn_step_begin) {
                        diag(
                            out,
                            PASS_TXN,
                            &m.path,
                            toks[i].line,
                            format!(
                                "`{}` calls `{}` directly — phase order must go through \
                                 `{}` (hand-rolled begin/stage/layer/commit sequences \
                                 drift from the canonical drivers)",
                                f.name,
                                cfg.txn_step_begin,
                                drivers.join("`/`")
                            ),
                        );
                    }
                }
            }
        }
    }
    // Rule 2: begin/commit/rollback triples, split-phase resolved over
    // the call graph.
    for pair in &cfg.txn_pairs {
        let body_calls = |name: &str| -> Vec<bool> {
            graph
                .nodes
                .iter()
                .map(|n| {
                    let m = &models[n.file_ix];
                    range_has_call(&m.toks, &m.fns[n.fn_ix].body, name)
                })
                .collect()
        };
        let reach_commit = graph.propagate(body_calls(&pair.commit));
        let reach_rollback = graph.propagate(body_calls(&pair.rollback));
        for (ix, n) in graph.nodes.iter().enumerate() {
            let m = &models[n.file_ix];
            let f = &m.fns[n.fn_ix];
            let toks = &m.toks;
            let Some(begin_ix) = first_call(toks, &f.body, &[pair.begin.as_str()]) else {
                continue;
            };
            let settles = [pair.commit.as_str(), pair.rollback.as_str()];
            if let Some(fin_ix) = first_call(toks, &f.body, &settles) {
                // Same-function pairing: no escape between begin and
                // the first commit/rollback.
                for i in begin_ix + 1..fin_ix {
                    if toks[i].is_punct('?') || toks[i].is_ident("return") {
                        diag(
                            out,
                            PASS_TXN,
                            &m.path,
                            toks[i].line,
                            format!(
                                "`{}` can exit between `{}` and `{}`/`{}` — every \
                                 return path must settle the transaction",
                                f.name, pair.begin, pair.commit, pair.rollback
                            ),
                        );
                    }
                }
                continue;
            }
            if drivers.iter().any(|d| range_has_call(toks, &f.body, d)) {
                continue; // delegated to a canonical driver
            }
            let mut ancestors = graph.callers_of(ix);
            ancestors.insert(ix);
            if ancestors.iter().any(|&a| reach_commit[a] && reach_rollback[a]) {
                continue; // split-phase: some caller chain settles it
            }
            diag(
                out,
                PASS_TXN,
                &m.path,
                toks[begin_ix].line,
                format!(
                    "`{}` calls `{}` but no caller chain settles it (no path through \
                     the call graph reaches both `{}` and `{}`)",
                    f.name, pair.begin, pair.commit, pair.rollback
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Pass 2: pin-conservation
// ---------------------------------------------------------------------------

/// Per configured scope file: every non-test function that acquires a
/// pin (calls an `acquire` method) must either release it (`release`
/// call), record it in a tracked collection (`trackers` identifier —
/// e.g. `band_pins`, drained by a paired release helper), or hand it
/// to a tracked drain-side registry (`delegates` call — e.g.
/// `mark_staged`, drained at `end_iteration`) — in the same function,
/// OR in a callee reachable through the call graph (pin delegation
/// across files: acquiring here and settling in a helper is
/// conserving). Plus a definitions check: the drain-side file must
/// actually define the registry API the scopes rely on.
pub fn pin_conservation(
    models: &[FileModel],
    graph: &CallGraph,
    cfg: &Config,
    out: &mut Vec<Diagnostic>,
) {
    for scope in &cfg.pin_scopes {
        let Some(mi) = models.iter().position(|m| m.path.ends_with(&scope.file)) else {
            continue;
        };
        let m = &models[mi];
        let toks = &m.toks;
        for (fi, f) in m.fns.iter().enumerate() {
            if f.is_test || m.file_is_test {
                continue;
            }
            let acquires: Vec<&str> = scope.acquire.iter().map(|s| s.as_str()).collect();
            let Some(acq_ix) = first_call(toks, &f.body, &acquires) else { continue };
            // Acquire *definitions* are exempt via is_call; also exempt
            // the release helpers themselves if they re-pin internally.
            let conserves = scope.release.iter().any(|r| range_has_call(toks, &f.body, r))
                || scope.delegates.iter().any(|d| range_has_call(toks, &f.body, d))
                || scope
                    .trackers
                    .iter()
                    .any(|t| f.body.clone().any(|i| toks[i].is_ident(t)));
            // Transitive delegation: a callee (any depth) whose body
            // settles the pin also conserves.
            let settles_downstream = !conserves
                && graph.node_of(mi, fi).is_some_and(|ix| {
                    let reach = graph.reachable(ix);
                    reach.iter().enumerate().any(|(t, &r)| {
                        if !r {
                            return false;
                        }
                        let tn = &graph.nodes[t];
                        let tm = &models[tn.file_ix];
                        let tf = &tm.fns[tn.fn_ix];
                        scope
                            .release
                            .iter()
                            .chain(scope.delegates.iter())
                            .any(|name| range_has_call(&tm.toks, &tf.body, name))
                    })
                });
            if !conserves && !settles_downstream {
                diag(
                    out,
                    PASS_PINS,
                    &m.path,
                    toks[acq_ix].line,
                    format!(
                        "`{}` acquires a pin ({}) but neither releases it ({}), \
                         records it in a tracker ({}), delegates it ({}), nor hands \
                         it to a callee that settles it — pins leak across aborts",
                        f.name,
                        scope.acquire.join("/"),
                        or_none(&scope.release),
                        or_none(&scope.trackers),
                        or_none(&scope.delegates),
                    ),
                );
            }
        }
    }
    for defs in &cfg.pin_defs {
        let Some(m) = models.iter().find(|m| m.path.ends_with(&defs.file)) else {
            // A configured drain-side file that does not exist is
            // itself a violation: the conservation argument depends
            // on it.
            diag(
                out,
                PASS_PINS,
                &defs.file,
                1,
                format!("configured drain-side file `{}` not found in scan set", defs.file),
            );
            continue;
        };
        for name in &defs.must_define {
            let defined = m.fns.iter().any(|f| f.name == *name);
            if !defined {
                diag(
                    out,
                    PASS_PINS,
                    &m.path,
                    1,
                    format!(
                        "drain-side API `{}` is not defined in `{}` — pin \
                         delegation has no drain",
                        name, defs.file
                    ),
                );
            }
        }
    }
}

fn or_none(v: &[String]) -> String {
    if v.is_empty() {
        "none configured".to_string()
    } else {
        v.join("/")
    }
}

// ---------------------------------------------------------------------------
// Pass 3: no-panic serving paths
// ---------------------------------------------------------------------------

/// In non-test code under the configured modules: forbid `.unwrap()`,
/// `.expect(`, `panic!`, and indexing by integer literal
/// (`xs[0]`). Typed `ServeError`/`MemoryError`/`ClusterError` is the
/// serving-path contract.
pub fn no_panic(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    for m in models {
        if !in_module_scope(&m.path, &cfg.no_panic_modules) || m.file_is_test {
            continue;
        }
        let toks = &m.toks;
        for i in 0..toks.len() {
            if m.is_test_at(i) {
                continue;
            }
            let t = &toks[i];
            if t.kind != TokKind::Ident && !t.is_punct('[') {
                continue;
            }
            let prev_dot = i > 0 && toks[i - 1].is_punct('.');
            let next_open = toks.get(i + 1).map(|n| n.is_punct('(')).unwrap_or(false);
            if prev_dot && next_open && (t.is_ident("unwrap") || t.is_ident("expect")) {
                diag(
                    out,
                    PASS_NO_PANIC,
                    &m.path,
                    t.line,
                    format!(
                        "`.{}(` on a serving path — return a typed error instead \
                         (ServeError/MemoryError/ClusterError)",
                        t.text
                    ),
                );
                continue;
            }
            let next_bang = toks.get(i + 1).map(|n| n.is_punct('!')).unwrap_or(false);
            if next_bang && (t.is_ident("panic") || t.is_ident("todo") || t.is_ident("unimplemented"))
            {
                diag(
                    out,
                    PASS_NO_PANIC,
                    &m.path,
                    t.line,
                    format!("`{}!` on a serving path — return a typed error instead", t.text),
                );
                continue;
            }
            // Indexing by integer literal: `ident[0]` / `)[0]` / `][0]`.
            if t.is_punct('[') && i > 0 {
                let indexable = toks[i - 1].kind == TokKind::Ident
                    || toks[i - 1].is_punct(')')
                    || toks[i - 1].is_punct(']');
                let lit_index = toks.get(i + 1).map(|n| n.kind == TokKind::Num).unwrap_or(false)
                    && toks.get(i + 2).map(|n| n.is_punct(']')).unwrap_or(false);
                if indexable && lit_index {
                    diag(
                        out,
                        PASS_NO_PANIC,
                        &m.path,
                        t.line,
                        "indexing by integer literal on a serving path — use \
                         `.get(n)` / `.first()` and handle the miss"
                            .to_string(),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pass 4: hot-path clone ban (direct sites)
// ---------------------------------------------------------------------------

/// Inside any function tagged `// sparselint: hot`: forbid the
/// configured allocating method calls (`.clone()`, `.to_vec()`), the
/// configured container constructors (`Vec::new`,
/// `Vec::with_capacity`, ...), and their macro forms (`vec!` when
/// `vec` is listed). Complements the runtime clone-probe: the probe
/// proves a run was clone-free, this proves the code cannot regress.
/// `hot-path-reach` below extends the same ban through callees.
pub fn hot_path(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    for m in models {
        let toks = &m.toks;
        for f in m.fns.iter().filter(|f| f.is_hot) {
            for i in f.body.clone() {
                let t = &toks[i];
                if t.kind != TokKind::Ident {
                    continue;
                }
                let prev_dot = i > 0 && toks[i - 1].is_punct('.');
                let next_open = toks.get(i + 1).map(|n| n.is_punct('(')).unwrap_or(false);
                if prev_dot && next_open && cfg.hot_banned_methods.iter().any(|b| t.is_ident(b)) {
                    diag(
                        out,
                        PASS_HOT,
                        &m.path,
                        t.line,
                        format!(
                            "`.{}(` inside hot function `{}` — steady-decode loops \
                             are zero-alloc (reuse scratch buffers)",
                            t.text, f.name
                        ),
                    );
                    continue;
                }
                if cfg.hot_banned_ctors.iter().any(|b| t.is_ident(b)) {
                    // `Ctor::new(` / `Ctor::with_capacity(` / `ctor!`
                    let ctor_call = toks.get(i + 1).map(|n| n.is_punct(':')).unwrap_or(false)
                        && toks.get(i + 2).map(|n| n.is_punct(':')).unwrap_or(false)
                        && toks
                            .get(i + 3)
                            .map(|n| n.is_ident("new") || n.is_ident("with_capacity"))
                            .unwrap_or(false);
                    let macro_call = toks.get(i + 1).map(|n| n.is_punct('!')).unwrap_or(false);
                    if ctor_call || macro_call {
                        diag(
                            out,
                            PASS_HOT,
                            &m.path,
                            t.line,
                            format!(
                                "fresh `{}` allocation inside hot function `{}` — \
                                 steady-decode loops reuse scratch buffers",
                                t.text, f.name
                            ),
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Interprocedural obligation propagation (panic-path, hot-path-reach)
// ---------------------------------------------------------------------------

/// Human-readable dirty chain from `start` down to a direct site:
/// `helper -> deep (src/util/stats.rs:12 .unwrap())`. Bounded so a
/// cycle or a pathological chain cannot explode the message.
fn trace_chain(
    models: &[FileModel],
    graph: &CallGraph,
    start: usize,
    direct: &[Option<(u32, String)>],
    dirty: &[bool],
) -> String {
    let mut chain: Vec<String> = Vec::new();
    let mut seen: HashSet<usize> = HashSet::new();
    let mut cur = Some(start);
    while let Some(ix) = cur {
        if seen.contains(&ix) || chain.len() >= 6 {
            break;
        }
        seen.insert(ix);
        let n = &graph.nodes[ix];
        if let Some((line, what)) = &direct[ix] {
            chain.push(format!(
                "{} ({}:{} {})",
                n.name,
                short_path(&models[n.file_ix].path),
                line,
                what
            ));
            break;
        }
        chain.push(n.name.clone());
        cur = n.resolved.iter().copied().find(|&t| dirty[t] && !seen.contains(&t));
    }
    chain.join(" -> ")
}

/// Interprocedural no-panic: a serving-scope function is flagged at
/// the call site of any callee that *transitively* reaches an
/// unjustified `.unwrap()` / `.expect(` / `panic!` / `todo!` /
/// `unimplemented!`. Reported only at the serving-scope frontier —
/// callees that are themselves in scope get their own report (or are
/// caught by the direct `no-panic` pass), so one panic does not fan
/// out into a report per transitive caller. A justified allow at the
/// marker (`no-panic` or `panic-path`) stops propagation at the
/// source; an allow at the frontier call line suppresses that edge.
pub fn panic_path(
    models: &[FileModel],
    graph: &CallGraph,
    cfg: &Config,
    out: &mut Vec<Diagnostic>,
) {
    if cfg.panic_path_modules.is_empty() {
        return;
    }
    let n_nodes = graph.nodes.len();
    let mut direct: Vec<Option<(u32, String)>> = vec![None; n_nodes];
    for (ix, node) in graph.nodes.iter().enumerate() {
        if node.is_test {
            continue;
        }
        let m = &models[node.file_ix];
        let f = &m.fns[node.fn_ix];
        let toks = &m.toks;
        let (s, e) = (f.body.start, f.body.end);
        for i in s..e {
            let t = &toks[i];
            if t.kind != TokKind::Ident {
                continue;
            }
            let mut hit: Option<String> = None;
            if t.is_ident("unwrap") || t.is_ident("expect") {
                if i > 0 && toks[i - 1].is_punct('.') && i + 1 < e && toks[i + 1].is_punct('(') {
                    hit = Some(format!(".{}()", t.text));
                }
            } else if (t.is_ident("panic") || t.is_ident("todo") || t.is_ident("unimplemented"))
                && i + 1 < e
                && toks[i + 1].is_punct('!')
            {
                hit = Some(format!("{}!", t.text));
            }
            if let Some(what) = hit {
                if !justified(m, t.line, &[PASS_NO_PANIC, PASS_PANIC_PATH]) {
                    direct[ix] = Some((t.line, what));
                    break;
                }
            }
        }
    }
    let dirty = graph.propagate(direct.iter().map(Option::is_some).collect());
    for node in &graph.nodes {
        if node.is_test {
            continue;
        }
        let m = &models[node.file_ix];
        if !in_module_scope(&m.path, &cfg.panic_path_modules) {
            continue;
        }
        let mut reported: HashSet<(u32, String)> = HashSet::new();
        for site in &node.resolved_sites {
            if justified(m, site.line, &[PASS_NO_PANIC, PASS_PANIC_PATH]) {
                continue;
            }
            for &t in &site.targets {
                if !dirty[t] {
                    continue;
                }
                let tn = &graph.nodes[t];
                if in_module_scope(&models[tn.file_ix].path, &cfg.panic_path_modules) {
                    continue; // reported at its own frontier
                }
                if !reported.insert((site.line, tn.name.clone())) {
                    continue;
                }
                let chain = trace_chain(models, graph, t, &direct, &dirty);
                diag(
                    out,
                    PASS_PANIC_PATH,
                    &m.path,
                    site.line,
                    format!("`{}` calls `{}` which can panic: {}", node.name, tn.name, chain),
                );
            }
        }
    }
}

/// Interprocedural hot-path allocation ban: a `// sparselint: hot`
/// function is flagged at the call site of any callee that
/// transitively reaches an unjustified banned method/ctor. Direct
/// sites inside the hot function are the `hot-path` pass's job; this
/// one closes the "hide the clone in a helper" loophole.
pub fn hot_path_reach(
    models: &[FileModel],
    graph: &CallGraph,
    cfg: &Config,
    out: &mut Vec<Diagnostic>,
) {
    if !cfg.hot_reach {
        return;
    }
    let n_nodes = graph.nodes.len();
    let mut direct: Vec<Option<(u32, String)>> = vec![None; n_nodes];
    for (ix, node) in graph.nodes.iter().enumerate() {
        if node.is_test {
            continue;
        }
        let m = &models[node.file_ix];
        let f = &m.fns[node.fn_ix];
        let toks = &m.toks;
        let (s, e) = (f.body.start, f.body.end);
        for i in s..e {
            let t = &toks[i];
            if t.kind != TokKind::Ident {
                continue;
            }
            let mut hit: Option<String> = None;
            if cfg.hot_banned_methods.iter().any(|b| t.is_ident(b)) {
                if i > 0 && toks[i - 1].is_punct('.') && i + 1 < e && toks[i + 1].is_punct('(') {
                    hit = Some(format!(".{}()", t.text));
                }
            } else if cfg.hot_banned_ctors.iter().any(|b| t.is_ident(b)) {
                if t.is_ident("vec") {
                    if i + 1 < e && toks[i + 1].is_punct('!') {
                        hit = Some("vec![]".to_string());
                    }
                } else if i + 3 < e && toks[i + 1].is_punct(':') && toks[i + 2].is_punct(':') {
                    let nx = &toks[i + 3];
                    if nx.is_ident("new") || nx.is_ident("with_capacity") || nx.is_ident("from") {
                        hit = Some(format!("{}::{}", t.text, nx.text));
                    }
                }
            }
            if let Some(what) = hit {
                if !justified(m, t.line, &[PASS_HOT, PASS_HOT_REACH]) {
                    direct[ix] = Some((t.line, what));
                    break;
                }
            }
        }
    }
    let dirty = graph.propagate(direct.iter().map(Option::is_some).collect());
    for node in &graph.nodes {
        if !node.is_hot {
            continue;
        }
        let m = &models[node.file_ix];
        let mut reported: HashSet<(u32, String)> = HashSet::new();
        for site in &node.resolved_sites {
            for &t in &site.targets {
                if !dirty[t] {
                    continue;
                }
                let tn = &graph.nodes[t];
                if !reported.insert((site.line, tn.name.clone())) {
                    continue;
                }
                let chain = trace_chain(models, graph, t, &direct, &dirty);
                diag(
                    out,
                    PASS_HOT_REACH,
                    &m.path,
                    site.line,
                    format!(
                        "hot fn `{}` calls `{}` which can allocate: {}",
                        node.name, tn.name, chain
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pass: step-typestate
// ---------------------------------------------------------------------------

#[derive(PartialEq, Clone, Copy)]
enum StepState {
    Closed,
    Open,
    Settled,
}

/// Linear typestate over the StepSession protocol, per function, in
/// body token order: `begin_step` opens; `stage` happens once, before
/// any phase call; `prefill_segment` precedes every `decode_layer`;
/// `commit`/`rollback` settle an open session. Only functions that
/// call the configured `begin` are checked — `stage`/`commit`/
/// `rollback` are generic method names elsewhere. A settled session
/// may settle again (branch arms commit/rollback on different paths),
/// and a function ending with the session open is flagged at its last
/// `begin` line.
pub fn step_typestate(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    let Some(ss) = &cfg.step_session else { return };
    let names = [
        ss.begin.as_str(),
        ss.stage.as_str(),
        ss.prefill.as_str(),
        ss.decode.as_str(),
        ss.commit.as_str(),
        ss.rollback.as_str(),
    ];
    for m in models {
        let toks = &m.toks;
        for f in &m.fns {
            let seq: Vec<usize> = f
                .body
                .clone()
                .filter(|&i| names.iter().any(|n| is_call(toks, i, n)))
                .collect();
            if !seq.iter().any(|&i| toks[i].is_ident(&ss.begin)) {
                continue;
            }
            let mut state = StepState::Closed;
            let mut staged = false;
            let mut saw_decode = false;
            for &i in &seq {
                let t = &toks[i];
                let line = t.line;
                if t.is_ident(&ss.begin) {
                    if state == StepState::Open {
                        diag(
                            out,
                            PASS_STEP,
                            &m.path,
                            line,
                            format!(
                                "`{}`: `{}` while a session is already open",
                                f.name, ss.begin
                            ),
                        );
                    }
                    state = StepState::Open;
                    staged = false;
                    saw_decode = false;
                } else if t.is_ident(&ss.stage) {
                    if state != StepState::Open {
                        diag(
                            out,
                            PASS_STEP,
                            &m.path,
                            line,
                            format!("`{}`: `{}` outside an open session", f.name, ss.stage),
                        );
                    } else if staged {
                        diag(
                            out,
                            PASS_STEP,
                            &m.path,
                            line,
                            format!("`{}`: `{}` called twice in one session", f.name, ss.stage),
                        );
                    } else if saw_decode {
                        diag(
                            out,
                            PASS_STEP,
                            &m.path,
                            line,
                            format!("`{}`: `{}` after a phase call", f.name, ss.stage),
                        );
                    }
                    staged = true;
                } else if t.is_ident(&ss.prefill) {
                    if state != StepState::Open {
                        diag(
                            out,
                            PASS_STEP,
                            &m.path,
                            line,
                            format!("`{}`: `{}` outside an open session", f.name, ss.prefill),
                        );
                    }
                    if saw_decode {
                        diag(
                            out,
                            PASS_STEP,
                            &m.path,
                            line,
                            format!(
                                "`{}`: `{}` after `{}` — prefill precedes decode",
                                f.name, ss.prefill, ss.decode
                            ),
                        );
                    }
                } else if t.is_ident(&ss.decode) {
                    if state != StepState::Open {
                        diag(
                            out,
                            PASS_STEP,
                            &m.path,
                            line,
                            format!("`{}`: `{}` outside an open session", f.name, ss.decode),
                        );
                    }
                    saw_decode = true;
                } else {
                    // commit or rollback
                    if state == StepState::Closed {
                        diag(
                            out,
                            PASS_STEP,
                            &m.path,
                            line,
                            format!("`{}`: `{}` with no open session", f.name, t.text),
                        );
                    }
                    state = StepState::Settled;
                }
            }
            if state == StepState::Open {
                let last_begin = seq
                    .iter()
                    .filter(|&&i| toks[i].is_ident(&ss.begin))
                    .map(|&i| toks[i].line)
                    .max()
                    .unwrap_or(f.line);
                diag(
                    out,
                    PASS_STEP,
                    &m.path,
                    last_begin,
                    format!("`{}`: session opened but never committed or rolled back", f.name),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pass: unit-dim
// ---------------------------------------------------------------------------

/// Suffix-convention dimensions. `Numeric` is a bare literal;
/// `NoDim` an ident without a recognized suffix. Only the five unit
/// dims ever appear in a diagnostic — mixing with an unknown term is
/// never reported (sound: no claim without evidence).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dim {
    S,
    Us,
    Bytes,
    Blocks,
    PerS,
    Numeric,
    NoDim,
}

impl Dim {
    fn is_unit(self) -> bool {
        matches!(self, Dim::S | Dim::Us | Dim::Bytes | Dim::Blocks | Dim::PerS)
    }
    fn name(self) -> &'static str {
        match self {
            Dim::S => "S",
            Dim::Us => "US",
            Dim::Bytes => "BYTES",
            Dim::Blocks => "BLOCKS",
            Dim::PerS => "PER_S",
            Dim::Numeric => "NUMERIC",
            Dim::NoDim => "NODIM",
        }
    }
}

/// Longest suffix first: `_bytes_per_s` must win over `_bytes`/`_s`.
const DIM_SUFFIXES: &[(&str, Dim)] = &[
    ("_bytes_per_s", Dim::PerS),
    ("_per_s", Dim::PerS),
    ("_us", Dim::Us),
    ("_bytes", Dim::Bytes),
    ("_blocks", Dim::Blocks),
    ("_s", Dim::S),
];

fn ident_dim(name: &str) -> Option<Dim> {
    DIM_SUFFIXES.iter().find(|(suf, _)| name.ends_with(suf)).map(|&(_, d)| d)
}

/// Dim of the term ending just before `toks[i]`, or None if unknown.
/// Matched `[...]` index chains are skipped so `xs[i]` types by `xs`.
fn term_before(toks: &[Tok], i: usize, lo: usize) -> Option<Dim> {
    let lo = lo as isize;
    let mut j = i as isize - 1;
    while j >= lo && toks[j as usize].is_punct(']') {
        let mut d = 1i32;
        j -= 1;
        while j >= lo && d > 0 {
            if toks[j as usize].is_punct(']') {
                d += 1;
            } else if toks[j as usize].is_punct('[') {
                d -= 1;
            }
            j -= 1;
        }
    }
    if j < lo {
        return None;
    }
    let t = &toks[j as usize];
    if t.kind == TokKind::Num {
        return Some(Dim::Numeric);
    }
    if t.kind != TokKind::Ident {
        return None; // `)` etc: a call result, unknown
    }
    Some(ident_dim(&t.text).unwrap_or(Dim::NoDim))
}

/// Dim of the term starting just after `toks[i]`. Walks dotted /
/// `::` chains to the last ident (`self.stall_s`, `r.mean_s`); a
/// trailing `(` makes it a call — unknown.
fn term_after(toks: &[Tok], i: usize, hi: usize) -> Option<Dim> {
    let mut j = i + 1;
    while j < hi && (toks[j].is_punct('&') || toks[j].is_ident("mut")) {
        j += 1;
    }
    if j >= hi {
        return None;
    }
    let t = &toks[j];
    if t.kind == TokKind::Num {
        return Some(Dim::Numeric);
    }
    if t.kind != TokKind::Ident {
        return None;
    }
    let mut last = j;
    let mut k = j;
    while k + 2 < hi
        && (toks[k + 1].is_punct('.') || (toks[k + 1].is_punct(':') && toks[k + 2].is_punct(':')))
    {
        let step = if toks[k + 1].is_punct('.') { 2 } else { 3 };
        if k + step < hi && toks[k + step].kind == TokKind::Ident {
            k += step;
            last = k;
        } else {
            break;
        }
    }
    if last + 1 < hi && toks[last + 1].is_punct('(') {
        return None; // call result unknown
    }
    Some(ident_dim(&toks[last].text).unwrap_or(Dim::NoDim))
}

enum RhsTerm {
    D(Dim),
    Num(String),
    Op(char),
}

/// Dim of a SIMPLE rhs expression (terms and `+ - * /`, no parens
/// except the sanctioned converter call). Knows the algebra the cost
/// model uses: `bytes / bytes_per_s = s`, `s * 1e6 = us` (the sole
/// legal conversion, alongside `secs_to_us(..)`), same-dim ratio is
/// dimensionless. Returns None on anything it cannot prove — an
/// unknown rhs never produces a finding.
fn rhs_dim(toks: &[Tok], start: usize, hi: usize, converter: &str) -> Option<Dim> {
    let mut terms: Vec<RhsTerm> = Vec::new();
    let mut i = start;
    while i < hi {
        let t = &toks[i];
        if t.is_punct(';') {
            break;
        }
        if t.kind == TokKind::Ident
            && t.text == converter
            && i + 1 < hi
            && toks[i + 1].is_punct('(')
        {
            // sanctioned converter: a US term; skip its arguments
            let mut d = 1i32;
            let mut j = i + 2;
            while j < hi && d > 0 {
                if toks[j].is_punct('(') {
                    d += 1;
                } else if toks[j].is_punct(')') {
                    d -= 1;
                }
                j += 1;
            }
            terms.push(RhsTerm::D(Dim::Us));
            i = j;
            continue;
        }
        if t.is_punct('(') || t.is_punct('[') {
            return None; // complex expression: bail, no claim
        }
        if t.kind == TokKind::Ident {
            if t.is_ident("as") {
                i += 2; // skip the cast type
                continue;
            }
            let mut last = i;
            let mut k = i;
            while k + 2 < hi
                && (toks[k + 1].is_punct('.')
                    || (toks[k + 1].is_punct(':') && toks[k + 2].is_punct(':')))
            {
                let step = if toks[k + 1].is_punct('.') { 2 } else { 3 };
                if k + step < hi && toks[k + step].kind == TokKind::Ident {
                    k += step;
                    last = k;
                } else {
                    break;
                }
            }
            if last + 1 < hi && toks[last + 1].is_punct('(') {
                return None; // method call: unknown
            }
            let d = ident_dim(&toks[last].text)?; // undimensioned ident: bail
            terms.push(RhsTerm::D(d));
            i = last + 1;
            continue;
        }
        if t.kind == TokKind::Num {
            terms.push(RhsTerm::Num(t.text.clone()));
            i += 1;
            continue;
        }
        if t.is_punct('+') || t.is_punct('-') || t.is_punct('*') || t.is_punct('/') {
            if i + 1 < hi && toks[i + 1].is_punct('>') {
                return None; // `->`: we ran off the expression
            }
            terms.push(RhsTerm::Op(t.text.as_bytes()[0] as char));
            i += 1;
            continue;
        }
        if t.is_punct('.') {
            i += 1;
            continue;
        }
        return None; // anything else: bail
    }
    let mut cur = match terms.first()? {
        RhsTerm::Op(_) => return None,
        RhsTerm::Num(_) => Dim::Numeric,
        RhsTerm::D(d) => *d,
    };
    if terms.len() % 2 == 0 {
        return None; // trailing operator: malformed, no claim
    }
    let mut j = 1;
    while j < terms.len() {
        let op = match &terms[j] {
            RhsTerm::Op(c) => *c,
            _ => return None,
        };
        let (rd, rnum) = match &terms[j + 1] {
            RhsTerm::Num(s) => (Dim::Numeric, Some(s.as_str())),
            RhsTerm::D(d) => (*d, None),
            RhsTerm::Op(_) => return None,
        };
        match op {
            '+' | '-' => {
                if rd == Dim::Numeric || cur == Dim::Numeric {
                    // additive with a bare number keeps the dim
                } else if rd != cur {
                    return Some(cur); // mixed add: the binary check reports it
                }
            }
            '*' => {
                let is_mega = rnum
                    .map(|s| {
                        let n = s.replace('_', "");
                        n == "1e6" || n == "1000000" || n == "1e6f64"
                    })
                    .unwrap_or(false);
                if cur == Dim::S && is_mega {
                    cur = Dim::Us; // the one sanctioned inline conversion
                } else if rd == Dim::Numeric {
                    // scaling keeps the dim
                } else if cur == Dim::Numeric {
                    cur = rd;
                } else {
                    return None; // dim * dim: unknown product
                }
            }
            '/' => {
                if rd == Dim::Numeric {
                    // scaling keeps the dim
                } else if cur == Dim::Bytes && rd == Dim::PerS {
                    cur = Dim::S; // bytes / bytes_per_s = seconds
                } else if rd == cur {
                    cur = Dim::Numeric; // same-dim ratio
                } else {
                    return None;
                }
            }
            _ => return None,
        }
        j += 2;
    }
    if cur == Dim::Numeric {
        None
    } else {
        Some(cur)
    }
}

/// Unit-dimension checking over the configured cost-model files.
/// Reports binary `+`/`-` (and their compound assignments), `<`/`>`/
/// `==` comparisons mixing two *known* dims, and simple assignments
/// that put a provably-S expression into a `_us` lvalue (or any other
/// cross-dim pair) without going through `* 1e6` or the sanctioned
/// converter. Anything the little algebra cannot prove is silent.
pub fn unit_dim(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    let Some(units) = &cfg.units else { return };
    for m in models {
        if !units.files.iter().any(|seg| m.path.contains(seg.as_str())) {
            continue;
        }
        let toks = &m.toks;
        for f in &m.fns {
            let (s, e) = (f.body.start, f.body.end);
            let mut i = s;
            while i < e {
                let t = &toks[i];
                if t.is_punct('+') || t.is_punct('-') {
                    if i + 1 < e && toks[i + 1].is_punct('>') {
                        i += 2; // `->`
                        continue;
                    }
                    if i + 1 < e && toks[i + 1].is_punct('=') {
                        // compound assign: lhs op= rhs
                        let l = term_before(toks, i, s);
                        let r = term_after(toks, i + 1, e);
                        if let (Some(l), Some(r)) = (l, r) {
                            if l.is_unit() && r.is_unit() && l != r {
                                diag(
                                    out,
                                    PASS_UNIT,
                                    &m.path,
                                    t.line,
                                    format!(
                                        "`{}`: `{}=` mixes {} and {}",
                                        f.name,
                                        t.text,
                                        l.name(),
                                        r.name()
                                    ),
                                );
                            }
                        }
                        i += 2;
                        continue;
                    }
                    let l = term_before(toks, i, s);
                    let r = term_after(toks, i, e);
                    if let (Some(l), Some(r)) = (l, r) {
                        if l.is_unit() && r.is_unit() && l != r {
                            diag(
                                out,
                                PASS_UNIT,
                                &m.path,
                                t.line,
                                format!(
                                    "`{}`: `{}` mixes {} and {}",
                                    f.name,
                                    t.text,
                                    l.name(),
                                    r.name()
                                ),
                            );
                        }
                    }
                } else if t.is_punct('<') || t.is_punct('>') {
                    // generics produce undimensioned sides and stay silent
                    let r = if i + 1 < e && toks[i + 1].is_punct('=') {
                        term_after(toks, i + 1, e) // <= / >=
                    } else {
                        term_after(toks, i, e)
                    };
                    let l = term_before(toks, i, s);
                    if let (Some(l), Some(r)) = (l, r) {
                        if l.is_unit() && r.is_unit() && l != r {
                            diag(
                                out,
                                PASS_UNIT,
                                &m.path,
                                t.line,
                                format!(
                                    "`{}`: comparison mixes {} and {}",
                                    f.name,
                                    l.name(),
                                    r.name()
                                ),
                            );
                        }
                    }
                } else if t.is_punct('=') {
                    let prev_is_op_tail = i > s
                        && toks[i - 1].kind == TokKind::Punct
                        && matches!(
                            toks[i - 1].text.as_str(),
                            "=" | "!" | "<" | ">" | "+" | "-" | "*" | "/"
                        );
                    if prev_is_op_tail {
                        i += 1; // second char of a 2-char operator
                        continue;
                    }
                    if i + 1 < e && toks[i + 1].is_punct('=') {
                        // `==` comparison
                        let l = term_before(toks, i, s);
                        let r = term_after(toks, i + 1, e);
                        if let (Some(l), Some(r)) = (l, r) {
                            if l.is_unit() && r.is_unit() && l != r {
                                diag(
                                    out,
                                    PASS_UNIT,
                                    &m.path,
                                    t.line,
                                    format!(
                                        "`{}`: `==` mixes {} and {}",
                                        f.name,
                                        l.name(),
                                        r.name()
                                    ),
                                );
                            }
                        }
                        i += 2;
                        continue;
                    }
                    if i + 1 < e && toks[i + 1].is_punct('>') {
                        i += 2; // `=>` match arm
                        continue;
                    }
                    // simple assignment: lhs = rhs ;
                    if let Some(l) = term_before(toks, i, s) {
                        if l.is_unit() {
                            if let Some(r) = rhs_dim(toks, i + 1, e, &units.converter) {
                                if r.is_unit() && r != l {
                                    diag(
                                        out,
                                        PASS_UNIT,
                                        &m.path,
                                        t.line,
                                        format!(
                                            "`{}`: assigns {} expression to {} lvalue \
                                             without conversion",
                                            f.name,
                                            r.name(),
                                            l.name()
                                        ),
                                    );
                                }
                            }
                        }
                    }
                }
                i += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pass 5: dead-knob / dead-counter
// ---------------------------------------------------------------------------

/// Fields of `struct_name` in `struct_file`, with the struct-body
/// line of each. Token scan: inside the struct braces at depth 1, an
/// `ident :` where the previous significant token is `{`, `,` or
/// `pub` is a field. Attribute contents are skipped.
fn struct_fields(m: &FileModel, struct_name: &str) -> Vec<(String, u32)> {
    let toks = &m.toks;
    let mut fields = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if toks[i].is_ident("struct") && toks[i + 1].is_ident(struct_name) {
            // find `{` (skip generics), then scan depth-1 entries
            let mut j = i + 2;
            while j < toks.len() && !toks[j].is_punct('{') && !toks[j].is_punct(';') {
                j += 1;
            }
            if j >= toks.len() || toks[j].is_punct(';') {
                return fields; // tuple/unit struct: nothing to check
            }
            let mut depth = 1usize;
            let mut k = j + 1;
            let mut prev_sig: Option<&Tok> = Some(&toks[j]);
            while k < toks.len() && depth > 0 {
                let t = &toks[k];
                if t.is_punct('#') && toks.get(k + 1).map(|n| n.is_punct('[')).unwrap_or(false) {
                    // skip attribute
                    let mut d = 0usize;
                    k += 1;
                    while k < toks.len() {
                        if toks[k].is_punct('[') {
                            d += 1;
                        } else if toks[k].is_punct(']') {
                            d -= 1;
                            if d == 0 {
                                k += 1;
                                break;
                            }
                        }
                        k += 1;
                    }
                    continue;
                }
                if t.is_punct('{') {
                    depth += 1;
                } else if t.is_punct('}') {
                    depth -= 1;
                }
                if depth == 1
                    && t.kind == TokKind::Ident
                    && toks.get(k + 1).map(|n| n.is_punct(':')).unwrap_or(false)
                    && prev_sig
                        .map(|p| p.is_punct('{') || p.is_punct(',') || p.is_ident("pub"))
                        .unwrap_or(false)
                {
                    fields.push((t.text.clone(), t.line));
                }
                prev_sig = Some(t);
                k += 1;
            }
            return fields;
        }
        i += 1;
    }
    fields
}

/// A `.field` occurrence at token index `i` (ident preceded by `.`,
/// not a method call).
fn is_field_access(toks: &[Tok], i: usize, field: &str) -> bool {
    toks[i].is_ident(field)
        && i > 0
        && toks[i - 1].is_punct('.')
        && !toks.get(i + 1).map(|n| n.is_punct('(')).unwrap_or(false)
}

/// Classify the access at `i` as a write (assignment, compound
/// assignment, or mutating method call on the field).
fn is_write_access(toks: &[Tok], i: usize) -> bool {
    const WRITE_METHODS: &[&str] = &[
        "push",
        "extend",
        "insert",
        "record",
        "record_outcome",
        "observe",
        "add",
        "merge",
        "set",
        "clear",
    ];
    match toks.get(i + 1) {
        Some(n) if n.is_punct('=') => {
            // `=` yes, `==` no
            !toks.get(i + 2).map(|m| m.is_punct('=')).unwrap_or(false)
        }
        Some(n) if n.is_punct('+') || n.is_punct('-') || n.is_punct('*') || n.is_punct('/') => {
            toks.get(i + 2).map(|m| m.is_punct('=')).unwrap_or(false)
        }
        Some(n) if n.is_punct('.') => toks
            .get(i + 2)
            .map(|m| m.kind == TokKind::Ident && WRITE_METHODS.contains(&m.text.as_str()))
            .unwrap_or(false),
        _ => false,
    }
}

/// Every `ServingConfig` knob must be read outside the config module:
/// a knob nobody consults silently no-ops (exactly how `compute_s`
/// sat dead until PR 6).
pub fn dead_knob(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    let Some(dk) = &cfg.dead_knob else { return };
    let Some(def) = models.iter().find(|m| m.path.ends_with(&dk.struct_file)) else {
        return;
    };
    for (field, line) in struct_fields(def, &dk.struct_name) {
        let live = models.iter().any(|m| {
            if m.path.contains(&dk.exclude_dir) {
                return false;
            }
            (0..m.toks.len()).any(|i| is_field_access(&m.toks, i, &field))
        });
        if !live {
            diag(
                out,
                PASS_DEAD_KNOB,
                &def.path,
                line,
                format!(
                    "`{}.{}` is never read outside `{}` — dead knob (wire it or \
                     delete it)",
                    dk.struct_name, field, dk.exclude_dir
                ),
            );
        }
    }
}

/// Every `RunMetrics` counter must be written somewhere AND read by a
/// reporting surface (a `report_fns` method in the metrics file, or
/// any code under `report_dirs`). A counter that is incremented but
/// never reported is measurement theater; one that is reported but
/// never incremented reports garbage.
pub fn dead_counter(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    let Some(dc) = &cfg.dead_counter else { return };
    let Some(def) = models.iter().find(|m| m.path.ends_with(&dc.struct_file)) else {
        return;
    };
    for (field, line) in struct_fields(def, &dc.struct_name) {
        let mut written = false;
        let mut reported = false;
        for m in models {
            let in_report_dir = dc.report_dirs.iter().any(|d| m.path.contains(d.as_str()));
            let is_struct_file = m.path.ends_with(&dc.struct_file);
            for i in 0..m.toks.len() {
                if !is_field_access(&m.toks, i, &field) {
                    continue;
                }
                if is_write_access(&m.toks, i) {
                    written = true;
                    continue;
                }
                if in_report_dir {
                    reported = true;
                } else if is_struct_file {
                    if let Some(f) = m.fn_at(i) {
                        if dc.report_fns.iter().any(|rf| f.name == *rf) {
                            reported = true;
                        }
                    }
                }
            }
        }
        if !written {
            diag(
                out,
                PASS_DEAD_COUNTER,
                &def.path,
                line,
                format!(
                    "`{}.{}` is never written — the counter reports a constant",
                    dc.struct_name, field
                ),
            );
        }
        if !reported {
            diag(
                out,
                PASS_DEAD_COUNTER,
                &def.path,
                line,
                format!(
                    "`{}.{}` is never read by a reporting surface ({} / {}) — \
                     measurement theater",
                    dc.struct_name,
                    field,
                    dc.report_fns.join("/"),
                    dc.report_dirs.join(", ")
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Allow-grammar pass (meta)
// ---------------------------------------------------------------------------

/// Malformed allow comments (missing `-- <reason>`, unknown
/// directive) and unknown pass names are diagnostics themselves, and
/// cannot be suppressed.
pub fn allow_grammar(models: &[FileModel], out: &mut Vec<Diagnostic>) {
    for m in models {
        for a in &m.allows {
            if let Some(why) = &a.malformed {
                diag(out, PASS_ALLOW_GRAMMAR, &m.path, a.line, why.clone());
                continue;
            }
            if !KNOWN_PASSES.contains(&a.pass.as_str()) {
                diag(
                    out,
                    PASS_ALLOW_GRAMMAR,
                    &m.path,
                    a.line,
                    format!(
                        "allow names unknown pass `{}` (known: {})",
                        a.pass,
                        KNOWN_PASSES.join(", ")
                    ),
                );
            }
        }
    }
}
