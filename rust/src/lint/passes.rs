//! The five sparselint passes.
//!
//! Every pass walks token streams and the per-file function model —
//! no AST. Each diagnostic carries the pass name so allow comments
//! (`// sparselint: allow(<pass>) -- <reason>`) and `[[allow]]`
//! config entries can target it.

use super::config::Config;
use super::lexer::{Tok, TokKind};
use super::model::FileModel;
use super::Diagnostic;

pub const PASS_TXN: &str = "txn-pairing";
pub const PASS_PINS: &str = "pin-conservation";
pub const PASS_NO_PANIC: &str = "no-panic";
pub const PASS_HOT: &str = "hot-path";
pub const PASS_DEAD_KNOB: &str = "dead-knob";
pub const PASS_DEAD_COUNTER: &str = "dead-counter";
pub const PASS_ALLOW_GRAMMAR: &str = "allow-grammar";

/// Pass names an allow comment may reference.
pub const KNOWN_PASSES: &[&str] = &[
    PASS_TXN,
    PASS_PINS,
    PASS_NO_PANIC,
    PASS_HOT,
    PASS_DEAD_KNOB,
    PASS_DEAD_COUNTER,
];

fn diag(out: &mut Vec<Diagnostic>, pass: &str, file: &str, line: u32, msg: String) {
    out.push(Diagnostic { pass: pass.to_string(), file: file.to_string(), line, msg });
}

/// `toks[i]` is a *call* of `name`: ident with that text, followed by
/// `(`, not preceded by `fn` (definition). Method calls (`x.name(`)
/// and free calls both match.
fn is_call(toks: &[Tok], i: usize, name: &str) -> bool {
    if !toks[i].is_ident(name) {
        return false;
    }
    let called = toks.get(i + 1).map(|t| t.is_punct('(')).unwrap_or(false);
    let defined = i > 0 && toks[i - 1].is_ident("fn");
    called && !defined
}

/// Any call of `name` inside token range `r`.
fn range_has_call(toks: &[Tok], r: &std::ops::Range<usize>, name: &str) -> bool {
    r.clone().any(|i| is_call(toks, i, name))
}

/// First call of any of `names` inside `r`, by token index.
fn first_call(toks: &[Tok], r: &std::ops::Range<usize>, names: &[&str]) -> Option<usize> {
    r.clone().find(|&i| names.iter().any(|n| is_call(toks, i, n)))
}

// ---------------------------------------------------------------------------
// Pass 1: txn-pairing
// ---------------------------------------------------------------------------

/// Two rules, applied to ALL code including tests (figures, benches
/// and tests drive backends directly and must uphold phase order):
///
/// 1. Only the configured driver (`drive_step`) may call the
///    phase-entry method (`begin_step`) directly — anything else is a
///    hand-rolled phase order.
/// 2. For each begin/commit/rollback triple: a function calling
///    `begin` must either (a) contain `commit` or `rollback` with no
///    `?`/`return` escape between the begin and the first
///    commit/rollback, (b) delegate to the driver, or (c) live in a
///    file that implements the split-phase pattern (the file defines
///    paths through both `commit` and `rollback` call sites, i.e. the
///    session object begun here is finished by its commit/rollback
///    methods).
pub fn txn_pairing(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    for m in models {
        let toks = &m.toks;
        // Rule 1: direct step_begin callers.
        if !cfg.txn_step_begin.is_empty() {
            for f in &m.fns {
                if f.name == cfg.txn_driver {
                    continue;
                }
                for i in f.body.clone() {
                    if is_call(toks, i, &cfg.txn_step_begin) {
                        diag(
                            out,
                            PASS_TXN,
                            &m.path,
                            toks[i].line,
                            format!(
                                "`{}` calls `{}` directly — phase order must go through \
                                 `{}` (hand-rolled begin/stage/layer/commit sequences \
                                 drift from the canonical driver)",
                                f.name, cfg.txn_step_begin, cfg.txn_driver
                            ),
                        );
                    }
                }
            }
        }
        // Rule 2: begin/commit/rollback triples.
        for pair in &cfg.txn_pairs {
            let file_has_commit =
                m.fns.iter().any(|f| range_has_call(toks, &f.body, &pair.commit));
            let file_has_rollback =
                m.fns.iter().any(|f| range_has_call(toks, &f.body, &pair.rollback));
            for f in &m.fns {
                let Some(begin_ix) = first_call(toks, &f.body, &[&pair.begin]) else {
                    continue;
                };
                let finish = first_call(toks, &f.body, &[&pair.commit, &pair.rollback]);
                if let Some(fin_ix) = finish {
                    // Same-function pairing: no escape between begin
                    // and the first commit/rollback.
                    for i in begin_ix + 1..fin_ix {
                        if toks[i].is_punct('?') || toks[i].is_ident("return") {
                            diag(
                                out,
                                PASS_TXN,
                                &m.path,
                                toks[i].line,
                                format!(
                                    "`{}` can exit between `{}` and `{}`/`{}` — every \
                                     return path must settle the transaction",
                                    f.name, pair.begin, pair.commit, pair.rollback
                                ),
                            );
                        }
                    }
                    continue;
                }
                if range_has_call(toks, &f.body, &cfg.txn_driver) {
                    continue; // delegated to the canonical driver
                }
                if file_has_commit && file_has_rollback {
                    continue; // split-phase session: finished elsewhere in this file
                }
                diag(
                    out,
                    PASS_TXN,
                    &m.path,
                    toks[begin_ix].line,
                    format!(
                        "`{}` calls `{}` but neither this function nor this file \
                         reaches `{}`/`{}` — unfinished transaction",
                        f.name, pair.begin, pair.commit, pair.rollback
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pass 2: pin-conservation
// ---------------------------------------------------------------------------

/// Per configured scope file: every non-test function that acquires a
/// pin (calls an `acquire` method) must, in the same function, either
/// release it (`release` call), record it in a tracked collection
/// (`trackers` identifier — e.g. `band_pins`, drained by a paired
/// release helper), or hand it to a tracked drain-side registry
/// (`delegates` call — e.g. `mark_staged`, drained at
/// `end_iteration`). Plus a definitions check: the drain-side file
/// must actually define the registry API the scopes rely on.
pub fn pin_conservation(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    for scope in &cfg.pin_scopes {
        let Some(m) = models.iter().find(|m| m.path.ends_with(&scope.file)) else {
            continue;
        };
        let toks = &m.toks;
        for f in &m.fns {
            if f.is_test || m.file_is_test {
                continue;
            }
            let acquires: Vec<&str> = scope.acquire.iter().map(|s| s.as_str()).collect();
            let Some(acq_ix) = first_call(toks, &f.body, &acquires) else { continue };
            // Acquire *definitions* are exempt via is_call; also exempt
            // the release helpers themselves if they re-pin internally.
            let conserves = scope.release.iter().any(|r| range_has_call(toks, &f.body, r))
                || scope.delegates.iter().any(|d| range_has_call(toks, &f.body, d))
                || scope
                    .trackers
                    .iter()
                    .any(|t| f.body.clone().any(|i| toks[i].is_ident(t)));
            if !conserves {
                diag(
                    out,
                    PASS_PINS,
                    &m.path,
                    toks[acq_ix].line,
                    format!(
                        "`{}` acquires a pin ({}) but neither releases it ({}), \
                         records it in a tracker ({}), nor delegates it ({}) in \
                         this function — pins leak across aborts",
                        f.name,
                        scope.acquire.join("/"),
                        or_none(&scope.release),
                        or_none(&scope.trackers),
                        or_none(&scope.delegates),
                    ),
                );
            }
        }
    }
    for defs in &cfg.pin_defs {
        let Some(m) = models.iter().find(|m| m.path.ends_with(&defs.file)) else {
            // A configured drain-side file that does not exist is
            // itself a violation: the conservation argument depends
            // on it.
            diag(
                out,
                PASS_PINS,
                &defs.file,
                1,
                format!("configured drain-side file `{}` not found in scan set", defs.file),
            );
            continue;
        };
        for name in &defs.must_define {
            let defined = m
                .fns
                .iter()
                .any(|f| f.name == *name);
            if !defined {
                diag(
                    out,
                    PASS_PINS,
                    &m.path,
                    1,
                    format!(
                        "drain-side API `{}` is not defined in `{}` — pin \
                         delegation has no drain",
                        name, defs.file
                    ),
                );
            }
        }
    }
}

fn or_none(v: &[String]) -> String {
    if v.is_empty() {
        "none configured".to_string()
    } else {
        v.join("/")
    }
}

// ---------------------------------------------------------------------------
// Pass 3: no-panic serving paths
// ---------------------------------------------------------------------------

/// In non-test code under the configured modules: forbid `.unwrap()`,
/// `.expect(`, `panic!`, and indexing by integer literal
/// (`xs[0]`). Typed `ServeError`/`MemoryError`/`ClusterError` is the
/// serving-path contract.
pub fn no_panic(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    for m in models {
        let in_scope = cfg
            .no_panic_modules
            .iter()
            .any(|md| m.path.contains(&format!("src/{md}/")) || m.path.ends_with(&format!("src/{md}.rs")));
        if !in_scope || m.file_is_test {
            continue;
        }
        let toks = &m.toks;
        for i in 0..toks.len() {
            if m.is_test_at(i) {
                continue;
            }
            let t = &toks[i];
            if t.kind != TokKind::Ident && !t.is_punct('[') {
                continue;
            }
            let prev_dot = i > 0 && toks[i - 1].is_punct('.');
            let next_open = toks.get(i + 1).map(|n| n.is_punct('(')).unwrap_or(false);
            if prev_dot && next_open && (t.is_ident("unwrap") || t.is_ident("expect")) {
                diag(
                    out,
                    PASS_NO_PANIC,
                    &m.path,
                    t.line,
                    format!(
                        "`.{}(` on a serving path — return a typed error instead \
                         (ServeError/MemoryError/ClusterError)",
                        t.text
                    ),
                );
                continue;
            }
            let next_bang = toks.get(i + 1).map(|n| n.is_punct('!')).unwrap_or(false);
            if next_bang && (t.is_ident("panic") || t.is_ident("todo") || t.is_ident("unimplemented"))
            {
                diag(
                    out,
                    PASS_NO_PANIC,
                    &m.path,
                    t.line,
                    format!("`{}!` on a serving path — return a typed error instead", t.text),
                );
                continue;
            }
            // Indexing by integer literal: `ident[0]` / `)[0]` / `][0]`.
            if t.is_punct('[') && i > 0 {
                let indexable = toks[i - 1].kind == TokKind::Ident
                    || toks[i - 1].is_punct(')')
                    || toks[i - 1].is_punct(']');
                let lit_index = toks.get(i + 1).map(|n| n.kind == TokKind::Num).unwrap_or(false)
                    && toks.get(i + 2).map(|n| n.is_punct(']')).unwrap_or(false);
                if indexable && lit_index {
                    diag(
                        out,
                        PASS_NO_PANIC,
                        &m.path,
                        t.line,
                        "indexing by integer literal on a serving path — use \
                         `.get(n)` / `.first()` and handle the miss"
                            .to_string(),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pass 4: hot-path clone ban
// ---------------------------------------------------------------------------

/// Inside any function tagged `// sparselint: hot`: forbid the
/// configured allocating method calls (`.clone()`, `.to_vec()`), the
/// configured container constructors (`Vec::new`,
/// `Vec::with_capacity`, ...), and their macro forms (`vec!` when
/// `vec` is listed). Complements the runtime clone-probe: the probe
/// proves a run was clone-free, this proves the code cannot regress.
pub fn hot_path(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    for m in models {
        let toks = &m.toks;
        for f in m.fns.iter().filter(|f| f.is_hot) {
            for i in f.body.clone() {
                let t = &toks[i];
                if t.kind != TokKind::Ident {
                    continue;
                }
                let prev_dot = i > 0 && toks[i - 1].is_punct('.');
                let next_open = toks.get(i + 1).map(|n| n.is_punct('(')).unwrap_or(false);
                if prev_dot && next_open && cfg.hot_banned_methods.iter().any(|b| t.is_ident(b)) {
                    diag(
                        out,
                        PASS_HOT,
                        &m.path,
                        t.line,
                        format!(
                            "`.{}(` inside hot function `{}` — steady-decode loops \
                             are zero-alloc (reuse scratch buffers)",
                            t.text, f.name
                        ),
                    );
                    continue;
                }
                if cfg.hot_banned_ctors.iter().any(|b| t.is_ident(b)) {
                    // `Ctor::new(` / `Ctor::with_capacity(` / `ctor!`
                    let ctor_call = toks.get(i + 1).map(|n| n.is_punct(':')).unwrap_or(false)
                        && toks.get(i + 2).map(|n| n.is_punct(':')).unwrap_or(false)
                        && toks
                            .get(i + 3)
                            .map(|n| n.is_ident("new") || n.is_ident("with_capacity"))
                            .unwrap_or(false);
                    let macro_call = toks.get(i + 1).map(|n| n.is_punct('!')).unwrap_or(false);
                    if ctor_call || macro_call {
                        diag(
                            out,
                            PASS_HOT,
                            &m.path,
                            t.line,
                            format!(
                                "fresh `{}` allocation inside hot function `{}` — \
                                 steady-decode loops reuse scratch buffers",
                                t.text, f.name
                            ),
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pass 5: dead-knob / dead-counter
// ---------------------------------------------------------------------------

/// Fields of `struct_name` in `struct_file`, with the struct-body
/// line of each. Token scan: inside the struct braces at depth 1, an
/// `ident :` where the previous significant token is `{`, `,` or
/// `pub` is a field. Attribute contents are skipped.
fn struct_fields(m: &FileModel, struct_name: &str) -> Vec<(String, u32)> {
    let toks = &m.toks;
    let mut fields = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if toks[i].is_ident("struct") && toks[i + 1].is_ident(struct_name) {
            // find `{` (skip generics), then scan depth-1 entries
            let mut j = i + 2;
            while j < toks.len() && !toks[j].is_punct('{') && !toks[j].is_punct(';') {
                j += 1;
            }
            if j >= toks.len() || toks[j].is_punct(';') {
                return fields; // tuple/unit struct: nothing to check
            }
            let mut depth = 1usize;
            let mut k = j + 1;
            let mut prev_sig: Option<&Tok> = Some(&toks[j]);
            while k < toks.len() && depth > 0 {
                let t = &toks[k];
                if t.is_punct('#') && toks.get(k + 1).map(|n| n.is_punct('[')).unwrap_or(false) {
                    // skip attribute
                    let mut d = 0usize;
                    k += 1;
                    while k < toks.len() {
                        if toks[k].is_punct('[') {
                            d += 1;
                        } else if toks[k].is_punct(']') {
                            d -= 1;
                            if d == 0 {
                                k += 1;
                                break;
                            }
                        }
                        k += 1;
                    }
                    continue;
                }
                if t.is_punct('{') {
                    depth += 1;
                } else if t.is_punct('}') {
                    depth -= 1;
                }
                if depth == 1
                    && t.kind == TokKind::Ident
                    && toks.get(k + 1).map(|n| n.is_punct(':')).unwrap_or(false)
                    && prev_sig
                        .map(|p| p.is_punct('{') || p.is_punct(',') || p.is_ident("pub"))
                        .unwrap_or(false)
                {
                    fields.push((t.text.clone(), t.line));
                }
                prev_sig = Some(t);
                k += 1;
            }
            return fields;
        }
        i += 1;
    }
    fields
}

/// A `.field` occurrence at token index `i` (ident preceded by `.`,
/// not a method call).
fn is_field_access(toks: &[Tok], i: usize, field: &str) -> bool {
    toks[i].is_ident(field)
        && i > 0
        && toks[i - 1].is_punct('.')
        && !toks.get(i + 1).map(|n| n.is_punct('(')).unwrap_or(false)
}

/// Classify the access at `i` as a write (assignment, compound
/// assignment, or mutating method call on the field).
fn is_write_access(toks: &[Tok], i: usize) -> bool {
    const WRITE_METHODS: &[&str] = &[
        "push",
        "extend",
        "insert",
        "record",
        "record_outcome",
        "observe",
        "add",
        "merge",
        "set",
        "clear",
    ];
    match toks.get(i + 1) {
        Some(n) if n.is_punct('=') => {
            // `=` yes, `==` no
            !toks.get(i + 2).map(|m| m.is_punct('=')).unwrap_or(false)
        }
        Some(n) if n.is_punct('+') || n.is_punct('-') || n.is_punct('*') || n.is_punct('/') => {
            toks.get(i + 2).map(|m| m.is_punct('=')).unwrap_or(false)
        }
        Some(n) if n.is_punct('.') => toks
            .get(i + 2)
            .map(|m| m.kind == TokKind::Ident && WRITE_METHODS.contains(&m.text.as_str()))
            .unwrap_or(false),
        _ => false,
    }
}

/// Every `ServingConfig` knob must be read outside the config module:
/// a knob nobody consults silently no-ops (exactly how `compute_s`
/// sat dead until PR 6).
pub fn dead_knob(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    let Some(dk) = &cfg.dead_knob else { return };
    let Some(def) = models.iter().find(|m| m.path.ends_with(&dk.struct_file)) else {
        return;
    };
    for (field, line) in struct_fields(def, &dk.struct_name) {
        let live = models.iter().any(|m| {
            if m.path.contains(&dk.exclude_dir) {
                return false;
            }
            (0..m.toks.len()).any(|i| is_field_access(&m.toks, i, &field))
        });
        if !live {
            diag(
                out,
                PASS_DEAD_KNOB,
                &def.path,
                line,
                format!(
                    "`{}.{}` is never read outside `{}` — dead knob (wire it or \
                     delete it)",
                    dk.struct_name, field, dk.exclude_dir
                ),
            );
        }
    }
}

/// Every `RunMetrics` counter must be written somewhere AND read by a
/// reporting surface (a `report_fns` method in the metrics file, or
/// any code under `report_dirs`). A counter that is incremented but
/// never reported is measurement theater; one that is reported but
/// never incremented reports garbage.
pub fn dead_counter(models: &[FileModel], cfg: &Config, out: &mut Vec<Diagnostic>) {
    let Some(dc) = &cfg.dead_counter else { return };
    let Some(def) = models.iter().find(|m| m.path.ends_with(&dc.struct_file)) else {
        return;
    };
    for (field, line) in struct_fields(def, &dc.struct_name) {
        let mut written = false;
        let mut reported = false;
        for m in models {
            let in_report_dir = dc.report_dirs.iter().any(|d| m.path.contains(d.as_str()));
            let is_struct_file = m.path.ends_with(&dc.struct_file);
            for i in 0..m.toks.len() {
                if !is_field_access(&m.toks, i, &field) {
                    continue;
                }
                if is_write_access(&m.toks, i) {
                    written = true;
                    continue;
                }
                if in_report_dir {
                    reported = true;
                } else if is_struct_file {
                    if let Some(f) = m.fn_at(i) {
                        if dc.report_fns.iter().any(|rf| f.name == *rf) {
                            reported = true;
                        }
                    }
                }
            }
        }
        if !written {
            diag(
                out,
                PASS_DEAD_COUNTER,
                &def.path,
                line,
                format!(
                    "`{}.{}` is never written — the counter reports a constant",
                    dc.struct_name, field
                ),
            );
        }
        if !reported {
            diag(
                out,
                PASS_DEAD_COUNTER,
                &def.path,
                line,
                format!(
                    "`{}.{}` is never read by a reporting surface ({} / {}) — \
                     measurement theater",
                    dc.struct_name,
                    field,
                    dc.report_fns.join("/"),
                    dc.report_dirs.join(", ")
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Allow-grammar pass (meta)
// ---------------------------------------------------------------------------

/// Malformed allow comments (missing `-- <reason>`, unknown
/// directive) and unknown pass names are diagnostics themselves, and
/// cannot be suppressed.
pub fn allow_grammar(models: &[FileModel], out: &mut Vec<Diagnostic>) {
    for m in models {
        for a in &m.allows {
            if let Some(why) = &a.malformed {
                diag(out, PASS_ALLOW_GRAMMAR, &m.path, a.line, why.clone());
                continue;
            }
            if !KNOWN_PASSES.contains(&a.pass.as_str()) {
                diag(
                    out,
                    PASS_ALLOW_GRAMMAR,
                    &m.path,
                    a.line,
                    format!(
                        "allow names unknown pass `{}` (known: {})",
                        a.pass,
                        KNOWN_PASSES.join(", ")
                    ),
                );
            }
        }
    }
}
