//! Configuration: model architectures, hardware cost models, serving knobs.

pub mod hardware;
pub mod model;
pub mod serving;

pub use hardware::HardwareSpec;
pub use model::ModelSpec;
pub use serving::{IterModel, PrefillMode, ServingConfig, TransferKind};
