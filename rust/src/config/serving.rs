//! Serving configuration — every system knob of the paper, including the
//! ablation switches of Fig. 13 (SA / Offload / FT / WC / LP).

/// How prompt prefill is scheduled into hybrid batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefillMode {
    /// Whole prompt in one iteration (plain vLLM prefill).
    Plain,
    /// Sarathi-style chunked prefill (baseline; paper §2.1).
    Chunked,
    /// The paper's layer-segmented prefill (§3.4).
    LayerSegmented,
}

/// Which iteration-timing event model the simulator charges PCIe
/// traffic with (real backends measure wall time instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IterModel {
    /// Coarse two-stream model ([`crate::sim::two_stream_iter`]): demand
    /// misses are charged wholesale to the critical path.
    Coarse,
    /// Per-layer event model ([`crate::sim::layered_iter`]): layer-N
    /// misses are issued when layer N starts and overlap the remaining
    /// layers' compute; only copy time the compute window cannot absorb
    /// stalls the iteration.
    #[default]
    PerLayer,
}

/// Which HBM<->DRAM transfer engines are used (paper §3.2 / Fig. 13 "FT").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    /// Per-block cudaMemcpy baseline.
    Memcpy,
    /// FlashH2D (GPU-direct fused gather) + FlashD2H (CPU-assisted save).
    Flash,
    /// GPU-direct saving (Fig. 14b middle bar): fused but steals SMs.
    GpuDirectSave,
}

#[derive(Debug, Clone)]
pub struct ServingConfig {
    // ---- base scheduler constraints (Alg. 1 inputs) ----
    /// R_max: max requests per batch.
    pub r_max: usize,
    /// T_max: max tokens per batch (bounds prefill compute per iteration).
    pub t_max: usize,
    /// Fraction of the HBM KV pool usable as M_avl by Alg. 1.
    pub m_avl_frac: f64,

    // ---- DSA ----
    /// Sparse attention enabled (false = full attention, vanilla vLLM).
    pub sparse_attention: bool,
    /// Token budget for sparse attention (paper: 2048 -> 99% accuracy).
    pub token_budget: usize,
    /// Working-set history window w (paper Fig. 8: w = 12).
    pub ws_window: usize,

    // ---- hierarchical memory ----
    /// Offload KV blocks to DRAM (false = everything pinned in HBM).
    pub offload: bool,
    /// Transfer engine selection (FT ablation).
    pub transfer: TransferKind,
    /// Working-set-aware batch size control (WC ablation, Alg. 1).
    pub ws_batch_control: bool,
    /// Consecutive WS-control skips after which a decode stops being
    /// leapfrogged by younger requests (starvation guard: the planner
    /// stops packing behind it so FCFS progress is guaranteed).
    pub ws_starvation_k: usize,

    // ---- working-set prefetch (PF ablation) ----
    /// Stage each scheduled decode's predicted working set (the
    /// recency-ranked `WorkingSetTracker` union) into HBM ahead of the
    /// batch, so loading overlaps compute instead of stalling it.
    pub prefetch: bool,
    /// Cap on blocks staged per iteration: block *groups* for the
    /// simulator, per-head blocks for the real backend.
    pub max_prefetch_blocks: usize,
    /// Blend selection frequency into the prefetch ranking: the
    /// working-set union is ordered recency-first, then by each block's
    /// hit EWMA within the same recency tier (off = pure recency order,
    /// the `+PF` ablation rung).
    pub prefetch_freq_ranking: bool,

    // ---- simulator fidelity ----
    /// Iteration event model (simulator only): per-layer overlap vs the
    /// coarse two-stream model. The `bench` subcommand compares the two.
    pub iter_model: IterModel,
    /// Layer bands K of the synthetic selection process (simulator
    /// only): each band draws its own selection per decode step (shared
    /// drifting hot pool), so cache misses are discovered band by band
    /// as the decode phases run instead of being smeared uniformly
    /// across layers. 1 = the old iteration-granular draw. Clamped to
    /// `n_layers` by the backend.
    pub sim_selection_bands: usize,
    /// Churn skew across layer bands in [-1, 1] (simulator only):
    /// negative concentrates fresh picks — and therefore demand misses —
    /// in EARLY bands, positive in LATE bands; 0 is uniform. The total
    /// churn (aggregate miss volume) is preserved for any skew. The
    /// `bench` subcommand sweeps this into `BENCH_layer_model.json`.
    pub sim_layer_skew: f64,

    // ---- admission ----
    /// Reserve admitted requests' KV against an observed-completion
    /// estimate instead of the full prompt+max_new lifetime bound, and
    /// grow the reservation block-by-block as decoding proceeds. Admits
    /// more aggressively for short completions; oversubscription is safe
    /// because a mid-batch memory exhaustion now rolls back and evicts
    /// typed instead of abandoning the batch.
    pub admission_estimates: bool,
    /// Cross-request KV prefix sharing: admission consults a
    /// radix/longest-common-prefix index over block-aligned prompt
    /// hashes, matched prefix blocks are shared (refcounted, COW at the
    /// open tail) instead of re-prefilled, and the request reserves only
    /// its unmatched-suffix KV against the DRAM tier. Off by default:
    /// every pre-existing preset keeps exclusive per-request ownership
    /// byte-identically (`+PFX` is its own ablation rung).
    pub prefix_sharing: bool,

    // ---- prefill ----
    pub prefill_mode: PrefillMode,
    /// Chunk size for chunked prefill (paper: 2048).
    pub chunk_tokens: usize,
    /// maxInjectToken for layer-segmented prefill (paper: B * L).
    pub max_inject_tokens: usize,

    // ---- SLOs (goodput, Fig. 13) ----
    /// P99 TBT SLO as a multiple of a plain decode-iteration time.
    pub slo_tbt_factor: f64,
    /// Mean scheduling (queueing) delay bound, seconds.
    pub slo_queue_delay_s: f64,

    // ---- execution pipelining ----
    /// Step-executor pipeline depth. 1 = today's fully synchronous
    /// order (plan -> stage -> per-layer phases -> commit on one
    /// thread). 2 = two-stage pipelined executor: while the backend
    /// drives iteration N's `StepSession`, the scheduler speculatively
    /// plans iteration N+1's decode batch and stage hints into
    /// double-buffered slots, and the cost model charges the pipelined
    /// bound `iter = max(compute_N, plan_stage_{N+1})` instead of
    /// serializing plan+stage onto the critical path (the `+PIPE`
    /// ablation rung rides this knob). Values above 2 behave as 2:
    /// with one in-flight session there is only one plan to hide.
    pub pipeline_depth: usize,
}

impl ServingConfig {
    /// Full SparseServe (all three contributions on).
    pub fn sparseserve(token_budget: usize, chunk_tokens: usize, n_layers: usize) -> Self {
        Self {
            r_max: 64,
            t_max: chunk_tokens,
            m_avl_frac: 0.9,
            sparse_attention: true,
            token_budget,
            ws_window: 12,
            offload: true,
            transfer: TransferKind::Flash,
            ws_batch_control: true,
            ws_starvation_k: 4,
            prefetch: true,
            max_prefetch_blocks: 4096,
            prefetch_freq_ranking: true,
            iter_model: IterModel::PerLayer,
            sim_selection_bands: 4,
            sim_layer_skew: 0.0,
            // default-on (measured by the `bench` subcommand): estimate-
            // based reservations admit short completions earlier, and
            // oversubscription is safe because mid-batch exhaustion rolls
            // back and evicts typed (PR 3)
            admission_estimates: true,
            prefix_sharing: false,
            prefill_mode: PrefillMode::LayerSegmented,
            // paper §4.2: maxInjectToken = B * L for parity with chunked
            max_inject_tokens: chunk_tokens * n_layers,
            chunk_tokens,
            slo_tbt_factor: 25.0,
            slo_queue_delay_s: 2.0,
            // synchronous by default: the pipelined executor is its own
            // ablation rung (+PIPE), not part of the paper's system
            pipeline_depth: 1,
        }
    }

    /// Vanilla vLLM: full attention, no offload, chunked prefill.
    pub fn vllm(chunk_tokens: usize) -> Self {
        Self {
            r_max: 64,
            t_max: chunk_tokens,
            m_avl_frac: 0.9,
            sparse_attention: false,
            token_budget: usize::MAX,
            ws_window: 12,
            offload: false,
            transfer: TransferKind::Memcpy,
            ws_batch_control: false,
            ws_starvation_k: 4,
            prefetch: false,
            max_prefetch_blocks: 0,
            prefetch_freq_ranking: false,
            iter_model: IterModel::PerLayer,
            // selection fidelity is uniform across every system/ladder
            // rung (it models the WORKLOAD, not a serving mechanism)
            sim_selection_bands: 4,
            sim_layer_skew: 0.0,
            admission_estimates: false,
            prefix_sharing: false,
            prefill_mode: PrefillMode::Chunked,
            chunk_tokens,
            max_inject_tokens: chunk_tokens,
            slo_tbt_factor: 25.0,
            slo_queue_delay_s: 2.0,
            pipeline_depth: 1,
        }
    }

    /// vLLM-S: vLLM + dynamic sparse attention (KV still pinned in HBM).
    pub fn vllm_s(token_budget: usize, chunk_tokens: usize) -> Self {
        Self {
            sparse_attention: true,
            token_budget,
            ..Self::vllm(chunk_tokens)
        }
    }

    /// vLLM-SO: vLLM-S + naive offloading (per-block memcpy transfers,
    /// no batch control, chunked prefill).
    pub fn vllm_so(token_budget: usize, chunk_tokens: usize) -> Self {
        Self {
            offload: true,
            ..Self::vllm_s(token_budget, chunk_tokens)
        }
    }

    /// No-prefetch ablation: full SparseServe minus the working-set
    /// prefetcher — every selection miss is loaded on demand, on the
    /// critical path. Isolates the overlap the prefetcher earns.
    pub fn sparseserve_np(token_budget: usize, chunk_tokens: usize, n_layers: usize) -> Self {
        Self {
            prefetch: false,
            max_prefetch_blocks: 0,
            ..Self::sparseserve(token_budget, chunk_tokens, n_layers)
        }
    }

    /// Budget in blocks for a given model block size (ceil).
    pub fn budget_blocks(&self, block_size: usize) -> usize {
        self.token_budget.div_ceil(block_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_differ_as_in_paper() {
        let v = ServingConfig::vllm(2048);
        let s = ServingConfig::vllm_s(2048, 2048);
        let so = ServingConfig::vllm_so(2048, 2048);
        let ss = ServingConfig::sparseserve(2048, 2048, 32);
        assert!(!v.sparse_attention && !v.offload);
        assert!(s.sparse_attention && !s.offload);
        assert!(so.sparse_attention && so.offload && so.transfer == TransferKind::Memcpy);
        assert!(ss.offload && ss.transfer == TransferKind::Flash && ss.ws_batch_control);
        assert_eq!(ss.prefill_mode, PrefillMode::LayerSegmented);
        // paper parity: maxInjectToken = B * L
        assert_eq!(ss.max_inject_tokens, 2048 * 32);
        // prefetch: on for SparseServe, off for every baseline
        assert!(ss.prefetch && !v.prefetch && !s.prefetch && !so.prefetch);
        // frequency-blended prefetch ranking ships with the full system
        assert!(ss.prefetch_freq_ranking && !v.prefetch_freq_ranking);
        // admission estimates are default-on for the full system only
        // (measured by `bench`; see README "Performance")
        assert!(ss.admission_estimates && !v.admission_estimates && !so.admission_estimates);
        let np = ServingConfig::sparseserve_np(2048, 2048, 32);
        assert!(!np.prefetch && np.offload && np.ws_batch_control);
        // selection fidelity (layer bands, no skew) is identical across
        // every system so comparisons measure mechanisms, not workloads
        for cfg in [&v, &s, &so, &ss, &np] {
            assert_eq!(cfg.sim_selection_bands, 4);
            assert_eq!(cfg.sim_layer_skew, 0.0);
            // every preset is synchronous: the pipelined executor is a
            // separate ablation rung (+PIPE), never an implicit default
            assert_eq!(cfg.pipeline_depth, 1);
            // prefix sharing is its own ablation rung (+PFX): with the
            // knob off every preset keeps exclusive block ownership
            assert!(!cfg.prefix_sharing);
        }
    }

    #[test]
    fn budget_blocks_rounds_up() {
        let ss = ServingConfig::sparseserve(2048, 2048, 32);
        assert_eq!(ss.budget_blocks(32), 64);
        assert_eq!(ss.budget_blocks(30), 69); // 2048/30 = 68.27 -> 69
    }
}
