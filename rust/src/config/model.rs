//! Model architecture specs.
//!
//! `tiny-llm` / `tiny-gqa` are executed for real via PJRT artifacts;
//! `lwm-7b` / `llama3-8b` are the paper's models, used by the simulator
//! backend to reproduce paper-scale memory/latency dynamics.

use crate::util::json::Value;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    /// Tokens per KV block (the DSA selection / paging unit).
    pub block_size: usize,
    pub max_ctx: usize,
    pub rope_theta: f64,
    /// Bytes per KV element (f16 at paper scale, f32 for tiny artifacts).
    pub kv_dtype_bytes: usize,
}

impl ModelSpec {
    pub fn max_blocks(&self) -> usize {
        self.max_ctx / self.block_size
    }

    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Bytes of one KV block for ONE head and ONE layer (K and V planes).
    /// This is the transfer granularity of the paper's fragmented access
    /// pattern (16 KB for LWM-7B: 32 tok x 128 dim x 2 (K,V) x 2 B).
    pub fn block_bytes(&self) -> usize {
        self.block_size * self.head_dim * 2 * self.kv_dtype_bytes
    }

    /// KV bytes per token across all layers and kv heads.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.n_layers * self.n_kv_heads * self.head_dim * 2 * self.kv_dtype_bytes
    }

    /// Total parameters (for compute cost models).
    pub fn n_params(&self) -> usize {
        let attn = self.d_model
            * (self.n_heads * self.head_dim)
            * 2  // wq, wo
            + self.d_model * (self.n_kv_heads * self.head_dim) * 2; // wk, wv
        let ffn = 3 * self.d_model * self.ffn_dim;
        self.n_layers * (attn + ffn) + 2 * self.vocab * self.d_model
    }

    /// Parse the model section of an artifacts manifest.
    pub fn from_manifest(v: &Value) -> anyhow::Result<Self> {
        let m = v.get("model").ok_or_else(|| anyhow::anyhow!("manifest missing 'model'"))?;
        let f = |k: &str| -> anyhow::Result<usize> {
            m.get(k)
                .and_then(Value::as_usize)
                .ok_or_else(|| anyhow::anyhow!("model field '{k}' missing"))
        };
        Ok(Self {
            name: m
                .get("name")
                .and_then(Value::as_str)
                .unwrap_or("unknown")
                .to_string(),
            vocab: f("vocab")?,
            d_model: f("d_model")?,
            n_layers: f("n_layers")?,
            n_heads: f("n_heads")?,
            n_kv_heads: f("n_kv_heads")?,
            head_dim: f("head_dim")?,
            ffn_dim: f("ffn_dim")?,
            block_size: f("block_size")?,
            max_ctx: f("max_ctx")?,
            rope_theta: m.get("rope_theta").and_then(Value::as_f64).unwrap_or(10000.0),
            kv_dtype_bytes: 4, // artifacts are f32
        })
    }

    /// LWM-7B (llama2-7B architecture, 1M ctx window; paper caps at 32k).
    pub fn lwm_7b() -> Self {
        Self {
            name: "lwm-7b".into(),
            vocab: 32000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32, // MHA
            head_dim: 128,
            ffn_dim: 11008,
            block_size: 32,
            max_ctx: 32768,
            rope_theta: 10000.0,
            kv_dtype_bytes: 2, // f16 on the A100 testbed
        }
    }

    /// Llama3-8B-262k (GQA; paper caps prompts at 128k).
    pub fn llama3_8b() -> Self {
        Self {
            name: "llama3-8b".into(),
            vocab: 128256,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 8, // GQA
            head_dim: 128,
            ffn_dim: 14336,
            block_size: 32,
            max_ctx: 131072,
            rope_theta: 500000.0,
            kv_dtype_bytes: 2,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "lwm-7b" => Some(Self::lwm_7b()),
            "llama3-8b" => Some(Self::llama3_8b()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lwm_block_bytes_matches_paper() {
        // Paper §1: "only 16 KB per block for ... LWM-7B" (32-token blocks).
        assert_eq!(ModelSpec::lwm_7b().block_bytes(), 16 * 1024);
    }

    #[test]
    fn lwm_param_count_is_7b_scale() {
        let p = ModelSpec::lwm_7b().n_params();
        assert!((6_000_000_000..8_000_000_000).contains(&p), "{p}");
    }

    #[test]
    fn gqa_group() {
        assert_eq!(ModelSpec::llama3_8b().group(), 4);
        assert_eq!(ModelSpec::lwm_7b().group(), 1);
    }

    #[test]
    fn kv_bytes_per_token_lwm() {
        // 32 layers * 32 heads * 128 dim * 2 (K,V) * 2 B = 512 KiB / token
        assert_eq!(ModelSpec::lwm_7b().kv_bytes_per_token(), 512 * 1024);
    }

    #[test]
    fn manifest_parse() {
        let text = r#"{"model":{"name":"tiny-llm","vocab":256,"d_model":128,
            "n_layers":4,"n_heads":4,"n_kv_heads":4,"head_dim":32,
            "ffn_dim":512,"block_size":16,"max_ctx":2048,"rope_theta":10000.0}}"#;
        let v = crate::util::json::parse(text).unwrap();
        let spec = ModelSpec::from_manifest(&v).unwrap();
        assert_eq!(spec.max_blocks(), 128);
        assert_eq!(spec.name, "tiny-llm");
    }
}
