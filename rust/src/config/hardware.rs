//! Hardware cost models — the testbed substitute (DESIGN.md table).
//!
//! The paper's testbed is an A100-40GB + PCIe Gen4 (32 GB/s) + 256 GB
//! DRAM. This repo runs on CPU, so latency/bandwidth phenomena are
//! reproduced through calibrated cost models: every model constant below
//! is pinned to a number the paper reports (or a public A100 datasheet
//! figure), and the unit tests assert the derived curves match the
//! paper's measured points (Fig. 4: memcpy < 5 GB/s vs FlashH2D > 20 GB/s
//! and FlashD2H > 23 GB/s).

/// One GPU + host testbed.
#[derive(Debug, Clone)]
pub struct HardwareSpec {
    pub name: String,
    /// HBM usable for KV cache, bytes (A100 40 GB minus weights/activations).
    pub hbm_kv_bytes: usize,
    /// Host DRAM for offloaded KV, bytes.
    pub dram_bytes: usize,
    /// PCIe peak, bytes/s (Gen4 x16 = 32 GB/s).
    pub pcie_peak: f64,
    /// Per-cudaMemcpy call overhead, seconds (driver + launch).
    pub memcpy_overhead_s: f64,
    /// Single GPU-kernel launch overhead for the fused H2D gather, seconds.
    pub kernel_launch_s: f64,
    /// Fraction of PCIe peak the fused UVA gather sustains (FlashH2D).
    pub fused_h2d_eff: f64,
    /// Fraction of PCIe peak one big contiguous D2H memcpy sustains (FlashD2H).
    pub contig_d2h_eff: f64,
    /// Dense-compute throughput, FLOP/s (A100 bf16 ~312e12, derated).
    pub gpu_flops: f64,
    /// HBM bandwidth, bytes/s (A100 40GB: 1.55e12).
    pub hbm_bw: f64,
    /// Slowdown multiplier on model compute while a GPU-direct *save*
    /// kernel shares the SMs (paper Fig. 14b: prefill 1.28x with GPU-direct
    /// saving vs 1.0x with FlashD2H).
    pub gpu_save_interference: f64,
}

impl HardwareSpec {
    /// The paper's A100-40GB testbed.
    pub fn a100_40gb() -> Self {
        Self {
            name: "a100-40gb".into(),
            // 40 GB minus ~13.5 GB weights (7B fp16) minus activations /
            // workspace / fragmentation for 32k-token prefills — sized so a
            // capped 32k-prompt request still fits (the paper prevents
            // vLLM aborts by capping prompts, §4.1)
            hbm_kv_bytes: 18 * (1 << 30),
            dram_bytes: 256 * (1 << 30),
            pcie_peak: 32e9,
            // effective small-transfer overhead per cudaMemcpy (driver +
            // DMA setup + sync), calibrated so the Fig. 4 memcpy series
            // stays under 5 GB/s across 4-64 KB blocks
            memcpy_overhead_s: 12.0e-6,
            kernel_launch_s: 12.0e-6,
            fused_h2d_eff: 0.70,
            contig_d2h_eff: 0.80,
            gpu_flops: 150e12, // achievable bf16 with real kernels (~50% MFU)
            hbm_bw: 1.2e12,    // achievable of the 1.55 TB/s peak
            gpu_save_interference: 1.28,
        }
    }

    /// A tiny testbed matching the real CPU-executed tiny-llm runs
    /// (capacities scaled so cache-pressure ratios mirror the paper).
    pub fn tiny_testbed() -> Self {
        Self {
            name: "tiny".into(),
            hbm_kv_bytes: 2 * (1 << 20), // 2 MiB "HBM" KV cache
            dram_bytes: 256 * (1 << 20),
            pcie_peak: 32e9,
            memcpy_overhead_s: 12.0e-6,
            kernel_launch_s: 12.0e-6,
            fused_h2d_eff: 0.70,
            contig_d2h_eff: 0.80,
            gpu_flops: 4e9, // single CPU core at f32
            hbm_bw: 20e9,
            gpu_save_interference: 1.28,
        }
    }

    /// Effective bandwidth of per-block `cudaMemcpy` transfers (Fig. 4
    /// baseline): each block pays the call overhead.
    pub fn memcpy_bandwidth(&self, block_bytes: usize) -> f64 {
        let t = self.memcpy_overhead_s + block_bytes as f64 / self.pcie_peak;
        block_bytes as f64 / t
    }

    /// Time to move `n_blocks` blocks of `block_bytes` via per-block memcpy.
    pub fn memcpy_time(&self, n_blocks: usize, block_bytes: usize) -> f64 {
        n_blocks as f64 * (self.memcpy_overhead_s + block_bytes as f64 / self.pcie_peak)
    }

    /// Time for the fused GPU-direct gather (FlashH2D): one launch + all
    /// bytes at the sustained UVA rate.
    pub fn flash_h2d_time(&self, n_blocks: usize, block_bytes: usize) -> f64 {
        self.kernel_launch_s
            + (n_blocks * block_bytes) as f64 / (self.pcie_peak * self.fused_h2d_eff)
    }

    /// Critical-path time of CPU-assisted saving (FlashD2H): one contiguous
    /// D2H copy; the CPU scatter overlaps with GPU compute (paper §3.2.2).
    pub fn flash_d2h_time(&self, total_bytes: usize) -> f64 {
        self.memcpy_overhead_s + total_bytes as f64 / (self.pcie_peak * self.contig_d2h_eff)
    }

    /// Effective bandwidths for the Fig. 4 series. Fig. 4 streams a fixed
    /// total volume while varying the block size, so the launch overhead
    /// amortizes over `total / block_bytes` blocks.
    pub const FIG4_BURST_BYTES: usize = 4 << 20;

    pub fn flash_h2d_bandwidth(&self, block_bytes: usize) -> f64 {
        let n = Self::FIG4_BURST_BYTES / block_bytes;
        (n * block_bytes) as f64 / self.flash_h2d_time(n, block_bytes)
    }

    pub fn flash_d2h_bandwidth(&self, block_bytes: usize) -> f64 {
        let n = Self::FIG4_BURST_BYTES / block_bytes;
        (n * block_bytes) as f64 / self.flash_d2h_time(n * block_bytes)
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "a100-40gb" => Some(Self::a100_40gb()),
            "tiny" => Some(Self::tiny_testbed()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: f64 = 1e9;

    #[test]
    fn memcpy_bandwidth_matches_fig4() {
        let hw = HardwareSpec::a100_40gb();
        // Paper §1: 16 KB blocks via cudaMemcpy -> < 4-5 GB/s.
        let bw16k = hw.memcpy_bandwidth(16 * 1024);
        assert!(bw16k < 4.0 * GB, "16KB memcpy bw {bw16k}");
        assert!(bw16k > 0.5 * GB, "16KB memcpy bw {bw16k}");
        // stays under 6 GB/s across Fig. 4's block sizes (4-64 KB)
        for kb in [4, 8, 16, 32, 64] {
            assert!(hw.memcpy_bandwidth(kb * 1024) < 6.5 * GB);
        }
    }

    #[test]
    fn flash_h2d_exceeds_20gbps() {
        let hw = HardwareSpec::a100_40gb();
        for kb in [4, 8, 16, 32, 64] {
            let bw = hw.flash_h2d_bandwidth(kb * 1024);
            assert!(bw > 20.0 * GB, "{kb}KB: {bw}");
            assert!(bw <= hw.pcie_peak);
        }
    }

    #[test]
    fn flash_d2h_exceeds_23gbps() {
        let hw = HardwareSpec::a100_40gb();
        for kb in [4, 8, 16, 32, 64] {
            let bw = hw.flash_d2h_bandwidth(kb * 1024);
            assert!(bw > 23.0 * GB, "{kb}KB: {bw}");
        }
    }

    #[test]
    fn fused_beats_memcpy_at_every_block_size() {
        let hw = HardwareSpec::a100_40gb();
        for kb in [1, 4, 16, 64, 256] {
            assert!(hw.flash_h2d_bandwidth(kb * 1024) > hw.memcpy_bandwidth(kb * 1024));
        }
    }

    #[test]
    fn loading_ratio_matches_fig14a_order() {
        // Fig. 14a: FlashH2D cuts loading latency up to ~10x vs memcpy.
        let hw = HardwareSpec::a100_40gb();
        let n = 256; // blocks per iteration at batch 8
        let ratio = hw.memcpy_time(n, 16 * 1024) / hw.flash_h2d_time(n, 16 * 1024);
        assert!(ratio > 5.0 && ratio < 20.0, "ratio={ratio}");
    }
}
