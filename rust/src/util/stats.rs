//! Summary statistics for latency/throughput series (metrics + benches).

/// Online accumulator plus retained samples for percentiles.
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.samples.extend_from_slice(xs);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.sum() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// The one sanctioned seconds→microseconds conversion point. The
/// `unit-dim` lint pass knows `* 1e6` (and this helper) as the only
/// legal way to move a `_s` value into a `_us` slot — route every
/// conversion through here so the scattered-literal drift the pass
/// exists to catch can't reappear.
pub const fn secs_to_us(secs: f64) -> f64 {
    secs * 1e6
}

/// Pretty-print seconds adaptively (benches + reports).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs_to_us(secs))
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Pretty-print bytes/second.
pub fn fmt_bandwidth(bytes_per_sec: f64) -> String {
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    const MB: f64 = 1024.0 * 1024.0;
    if bytes_per_sec >= GB {
        format!("{:.2} GB/s", bytes_per_sec / GB)
    } else {
        format!("{:.2} MB/s", bytes_per_sec / MB)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let mut s = Series::new();
        s.extend(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138).abs() < 1e-3);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Series::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.p50() - 2.5).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_safe() {
        let s = Series::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert!(fmt_bandwidth(32.0 * 1024.0 * 1024.0 * 1024.0).starts_with("32.00 GB/s"));
    }
}
