//! Minimal JSON parser + writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null); used for `manifest.json`, `golden.json` and
//! config files. Not performance-critical: parsing happens once at startup.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `get` that treats JSON `null` as absent.
    pub fn get_nonnull(&self, key: &str) -> Option<&Value> {
        match self.get(key) {
            Some(Value::Null) | None => None,
            Some(v) => Some(v),
        }
    }
}

/// Parse error with byte offset for debuggability.
#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape char")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences byte-wise
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("bad utf-8"));
                    }
                    self.pos = end;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------- writing

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders for emitting results (benches, metrics dumps).
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Value>>(items: I) -> Value {
    Value::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_usize().unwrap(), 2);
        assert_eq!(a[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""A\t\\""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\\");
        let v = parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn get_nonnull_treats_null_as_absent() {
        let v = parse(r#"{"a": null, "b": 1}"#).unwrap();
        assert!(v.get_nonnull("a").is_none());
        assert!(v.get_nonnull("b").is_some());
        assert!(v.get_nonnull("missing").is_none());
    }
}
