//! Self-contained utility substrates.
//!
//! This repo builds fully offline against the vendored crate set of the
//! xla example (no serde / clap / rand / criterion / proptest), so the
//! small pieces those crates would normally provide are implemented here:
//!
//! - [`json`]: minimal JSON parser/serializer (manifests, goldens, configs)
//! - [`rng`]: splitmix/PCG PRNG + exponential/Poisson sampling (workloads)
//! - [`stats`]: mean/percentile/throughput summaries (metrics, benches)
//! - [`cli`]: flag-style argument parser (launcher)
//! - [`threadpool`]: fixed worker pool (FlashD2H scatter workers)
//! - [`prop`]: mini property-test harness (invariant tests)

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
