//! Fixed-size worker thread pool.
//!
//! Used by FlashD2H's CPU-assisted scatter stage (paper §3.2.2): after the
//! single contiguous device→host copy, worker threads redistribute the
//! staged KV rows into their DRAM blocks off the critical path.

use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Poison-tolerant lock: a job that panicked must not wedge the pool's
/// bookkeeping (the counter itself is a plain usize, always valid).
fn lock_pending<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    pub fn new(n_workers: usize) -> Self {
        assert!(n_workers > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let workers = (0..n_workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::Builder::new()
                    .name(format!("d2h-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = lock_pending(&rx);
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut n = lock_pending(lock);
                                *n -= 1;
                                if *n == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    // sparselint: allow(panic-path) -- pool construction happens at engine startup, before any request is admitted; failing to spawn OS threads is fatal by design
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, pending }
    }

    /// Submit a job; returns immediately.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock_pending(lock) += 1;
        }
        self.tx
            .as_ref()
            // sparselint: allow(panic-path) -- tx is only None after Drop::drop; submitting to a dropped pool is a use-after-shutdown bug, not a serving state
            .expect("pool shut down")
            .send(Box::new(f))
            // sparselint: allow(panic-path) -- workers only exit when the channel closes on Drop, so a send failure means the same use-after-shutdown bug
            .expect("workers alive");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock_pending(lock);
        while *n > 0 {
            n = cv.wait(n).unwrap_or_else(PoisonError::into_inner);
        }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_with_no_jobs_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn reusable_after_wait() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
            assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
