//! Minimal benchmarking harness (criterion is not vendored offline).
//!
//! Warm-up + timed batches with mean/p50/p99 reporting; used by the
//! `cargo bench` targets (all `harness = false`).

use std::time::Instant;

use super::stats::{fmt_duration, Series};

#[derive(Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    /// Case-specific side metrics (key, value) carried into the JSON
    /// point alongside the timing percentiles — e.g. the pipelined
    /// full-step row reports how much plan/stage time it hid.
    pub extra: Vec<(String, f64)>,
}

impl BenchResult {
    /// Attach a side metric to the result (builder-style).
    pub fn with_extra(mut self, key: &str, value: f64) -> Self {
        self.extra.push((key.to_string(), value));
        self
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  p50 {:>12}  p99 {:>12}  ({} iters)",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.p99_s),
            self.iters
        )
    }
}

/// Run `f` repeatedly for ~`budget_s` seconds (after `warmup` iterations)
/// and report per-iteration timing.
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, warmup: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Series::new();
    let start = Instant::now();
    let mut iters = 0usize;
    while start.elapsed().as_secs_f64() < budget_s || iters < 10 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        iters += 1;
        if iters >= 1_000_000 {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: samples.mean(),
        p50_s: samples.p50(),
        p99_s: samples.p99(),
        extra: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 0.02, 2, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.iters >= 10);
        assert!(r.mean_s >= 0.0);
        assert!(r.p99_s >= r.p50_s);
        assert!(r.line().contains("noop-ish"));
        let r = r.with_extra("hidden_s", 0.25);
        assert_eq!(r.extra, vec![("hidden_s".to_string(), 0.25)]);
    }
}
