//! Minimal flag-style CLI parser for the launcher and examples.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free
//! positional arguments. The launcher (`main.rs`) layers subcommands on
//! top of this.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_kinds() {
        // NOTE: a bare `--flag` followed by a non-flag token consumes it as
        // a value; boolean flags therefore go last or use `--flag=true`.
        let a = parse(&["serve", "pos2", "--rate", "0.5", "--name=x", "--verbose"]);
        assert_eq!(a.positional, vec!["serve", "pos2"]);
        assert_eq!(a.f64("rate", 0.0), 0.5);
        assert_eq!(a.get("name"), Some("x"));
        assert!(a.bool("verbose"));
        assert!(!a.bool("missing"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize("n", 3), 3);
        assert_eq!(a.get_or("mode", "sim"), "sim");
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = parse(&["--a", "--b", "v"]);
        assert!(a.bool("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
