//! Deterministic PRNG + workload-distribution sampling.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014): small, fast, statistically solid for
//! simulation workloads, and fully reproducible across runs — every
//! experiment in EXPERIMENTS.md fixes its seed.

/// PCG32 generator. `Copy`: two words of state, so undo scopes snapshot
/// it by value instead of `clone()` (which the hot-path allocation lint
/// would otherwise have to reason about).
#[derive(Debug, Clone, Copy)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Independent stream: generators with the same seed but different
    /// streams are uncorrelated (used to decouple arrival times from
    /// request lengths in the workload generator).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_wide(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Exponential with the given rate (mean 1/rate) — Poisson inter-arrivals.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal: exp(mu + sigma * N(0,1)). Long-context prompt-length
    /// distributions are heavy-tailed; LongBench per-task lengths are well
    /// approximated by a clamped log-normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[inline]
fn mul_wide(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::with_stream(7, 1);
        let mut b = Rng::with_stream(7, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exponential(0.25)).sum();
        let mean = sum / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
