//! Mini property-based testing harness (proptest is not vendored).
//!
//! `check` runs a property over many seeded random cases and, on failure,
//! reports the seed so the case replays deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this offline image)
//! use sparseserve::util::{prop, rng::Rng};
//! prop::check("sum commutes", 100, |rng: &mut Rng| {
//!     let (a, b) = (rng.below(1000), rng.below(1000));
//!     prop::assert_prop(a + b == b + a, "a+b != b+a")
//! });
//! ```

use super::rng::Rng;

pub type PropResult = Result<(), String>;

/// Assert helper returning a `PropResult`.
pub fn assert_prop(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert equality with a formatted message.
pub fn assert_eq_prop<T: PartialEq + std::fmt::Debug>(a: T, b: T, ctx: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

/// Run `cases` random instances of a property. Panics (failing the test)
/// with the offending seed on the first violated case.
pub fn check<F: FnMut(&mut Rng) -> PropResult>(name: &str, cases: u64, mut property: F) {
    // Base seed is overridable for replaying failures.
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed}; \
                 replay with PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 50, |rng| {
            let x = rng.below(100);
            assert_prop(x < 100, "below out of range")
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_reports_seed() {
        check("must fail", 10, |rng| {
            assert_prop(rng.below(10) < 5, "sometimes >= 5")
        });
    }

    #[test]
    fn assert_eq_prop_formats() {
        assert!(assert_eq_prop(1, 1, "eq").is_ok());
        let err = assert_eq_prop(1, 2, "eq").unwrap_err();
        assert!(err.contains("1") && err.contains("2"));
    }
}
