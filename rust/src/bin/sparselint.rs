//! `sparselint` driver: walk the repo's Rust sources, run the lint
//! passes, report `file:line: [pass] message` diagnostics.
//!
//! Usage:
//!   cargo run --release --bin sparselint
//!       [-- --config PATH --json PATH --pass NAME --emit-callgraph PATH]
//!
//! Exit codes: 0 clean, 1 violations found, 2 config/IO error.

use sparseserve::lint::{analyze_with, emit_callgraph, passes, Config, SourceFile};
use sparseserve::util::cli::Args;
use sparseserve::util::json;
use std::path::{Path, PathBuf};

const USAGE: &str = "\
sparselint: repo-invariant static analysis for SparseServe

USAGE:
    sparselint [--config PATH] [--json PATH] [--pass NAME]
               [--emit-callgraph PATH]

FLAGS:
    --config PATH          lint config (default: <manifest>/lint.toml)
    --json PATH            also write diagnostics + per-pass stats as JSON
    --pass NAME            run only the named pass
    --emit-callgraph PATH  dump the crate-wide call graph as JSON
    --help                 this text

Walks rust/src, rust/tests, rust/benches and examples/. Passes:
txn-pairing, pin-conservation, no-panic, hot-path, panic-path,
hot-path-reach, step-typestate, unit-dim, dead-knob, dead-counter
(plus allow-grammar on the suppression comments themselves). The
interprocedural passes resolve obligations over a crate-wide call
graph; split-phase transactions and pin delegation settle across
files. Suppress a finding in place with
    // sparselint: allow(<pass>) -- <reason>
or with a [[allow]] entry (with a reason) in lint.toml.

Exit codes: 0 clean, 1 violations, 2 config/IO error.";

fn main() {
    let args = Args::from_env();
    if args.bool("help") {
        println!("{USAGE}");
        return;
    }
    std::process::exit(run(&args));
}

fn run(args: &Args) -> i32 {
    let default_cfg = concat!(env!("CARGO_MANIFEST_DIR"), "/lint.toml").to_string();
    let cfg_path = args.get_or("config", &default_cfg);
    let cfg = match std::fs::read_to_string(&cfg_path) {
        Ok(text) => match Config::from_toml(&text) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("sparselint: {cfg_path}: {e}");
                return 2;
            }
        },
        Err(e) => {
            // The embedded copy of rust/lint.toml keeps the tool usable
            // from an unusual cwd, but an explicit --config must exist.
            if args.get("config").is_some() {
                eprintln!("sparselint: cannot read {cfg_path}: {e}");
                return 2;
            }
            eprintln!("sparselint: {cfg_path} not readable ({e}); using embedded config");
            Config::repo_default()
        }
    };

    // Scan roots relative to the config file's directory (the cargo
    // manifest dir), displayed relative to the repository root.
    let base = Path::new(&cfg_path)
        .parent()
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."));
    let roots: [(&str, &str); 4] = [
        ("src", "rust/src"),
        ("tests", "rust/tests"),
        ("benches", "rust/benches"),
        ("../examples", "examples"),
    ];
    let mut files = Vec::new();
    for (rel, display) in roots {
        let root = base.join(rel);
        if !root.is_dir() {
            continue;
        }
        let mut paths = Vec::new();
        if let Err(e) = collect_rs(&root, &mut paths) {
            eprintln!("sparselint: walking {}: {e}", root.display());
            return 2;
        }
        paths.sort();
        for p in paths {
            let src = match std::fs::read_to_string(&p) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("sparselint: reading {}: {e}", p.display());
                    return 2;
                }
            };
            let rel_path = p.strip_prefix(&root).unwrap_or(&p);
            let shown = format!("{display}/{}", rel_path.display()).replace('\\', "/");
            files.push(SourceFile { path: shown, src });
        }
    }
    if files.is_empty() {
        eprintln!("sparselint: no .rs files found under {}", base.display());
        return 2;
    }

    let only = args.get("pass");
    if let Some(name) = &only {
        let known = passes::KNOWN_PASSES.contains(&name.as_str())
            || name == passes::PASS_ALLOW_GRAMMAR;
        if !known {
            eprintln!(
                "sparselint: unknown pass `{name}` (known: {}, {})",
                passes::KNOWN_PASSES.join(", "),
                passes::PASS_ALLOW_GRAMMAR
            );
            return 2;
        }
    }

    if let Some(cg_path) = args.get("emit-callgraph") {
        let js = emit_callgraph(&files);
        if let Err(e) = std::fs::write(&cg_path, format!("{js}\n")) {
            eprintln!("sparselint: writing {cg_path}: {e}");
            return 2;
        }
        println!("sparselint: call graph written to {cg_path}");
    }

    let analysis = analyze_with(&files, &cfg, only.as_deref());
    let diags = &analysis.diags;
    for d in diags {
        println!("{d}");
    }
    if let Some(json_path) = args.get("json") {
        let doc = json::obj(vec![
            ("files_scanned", json::num(files.len() as f64)),
            ("fns", json::num(analysis.n_fns as f64)),
            ("call_edges", json::num(analysis.n_edges as f64)),
            ("violations", json::num(diags.len() as f64)),
            (
                "diagnostics",
                json::arr(diags.iter().map(|d| {
                    json::obj(vec![
                        ("pass", json::s(&d.pass)),
                        ("file", json::s(&d.file)),
                        ("line", json::num(d.line as f64)),
                        ("msg", json::s(&d.msg)),
                    ])
                })),
            ),
            (
                "passes",
                json::arr(analysis.stats.iter().map(|s| {
                    json::obj(vec![
                        ("name", json::s(&s.name)),
                        ("raw", json::num(s.raw as f64)),
                        ("kept", json::num(s.kept as f64)),
                        ("duration_us", json::num(s.micros as f64)),
                    ])
                })),
            ),
        ]);
        if let Err(e) = std::fs::write(json_path, format!("{doc}\n")) {
            eprintln!("sparselint: writing {json_path}: {e}");
            return 2;
        }
    }
    if diags.is_empty() {
        println!("sparselint: clean ({} files)", files.len());
        0
    } else {
        eprintln!("sparselint: {} violation(s) in {} files scanned", diags.len(), files.len());
        1
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}
