//! Self-contained stand-in for the `xla` (xla_extension / PJRT) bindings.
//!
//! The real three-layer path executes AOT-lowered HLO through the PJRT
//! CPU client via the `xla` crate, which links the prebuilt
//! `xla_extension` C++ library — not vendorable in this offline build.
//! This module keeps the crate self-contained:
//!
//! - host-side types ([`Literal`], [`ElementType`], [`ArrayShape`])
//!   are fully functional (shape/byte round-trips, used by
//!   `runtime::HostTensor` and its tests);
//! - device-side types ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`PjRtBuffer`]) fail fast at client creation with a clear message,
//!   so everything PJRT-gated (tests behind `artifacts_ready()`, the
//!   `serve` subcommand) degrades into a clean "backend unavailable"
//!   error instead of a link failure.
//!
//! Swapping in the real bindings is a one-line change: replace this
//! module with `xla = { ... }` in Cargo.toml and delete `use crate::xla`
//! from `runtime/`.

use std::path::Path;

/// Error type mirroring the bindings' debug-printable errors.
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT unavailable (stub `xla` module; vendor the \
         xla_extension bindings to enable real execution)"
    )))
}

/// Element types we exchange with the artifacts (f32 / s32 payloads;
/// `Pred` only so type dispatch has a genuine fallback arm).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::Pred => 1,
        }
    }
}

/// Host value with an element type: the interchange unit of `execute`.
pub enum Literal {
    Array {
        ty: ElementType,
        dims: Vec<i64>,
        bytes: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

/// Array shape view (dims + element type).
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Rust scalar types with an XLA element type.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le_bytes(b: &[u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: &[u8; 4]) -> Self {
        f32::from_le_bytes(*b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: &[u8; 4]) -> Self {
        i32::from_le_bytes(*b)
    }
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        bytes: &[u8],
    ) -> Result<Literal, Error> {
        let n: usize = dims.iter().product();
        if n * ty.byte_size() != bytes.len() {
            return Err(Error(format!(
                "literal size mismatch: {dims:?} x {ty:?} vs {} bytes",
                bytes.len()
            )));
        }
        Ok(Literal::Array {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: bytes.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        match self {
            Literal::Array { ty, dims, .. } => {
                Ok(ArrayShape { dims: dims.clone(), ty: *ty })
            }
            Literal::Tuple(_) => Err(Error("array_shape on a tuple literal".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        match self {
            Literal::Array { ty, bytes, .. } if *ty == T::TY => Ok(bytes
                .chunks_exact(4)
                .map(|c| T::from_le_bytes(c.try_into().expect("4-byte chunk")))
                .collect()),
            Literal::Array { ty, .. } => {
                Err(Error(format!("to_vec type mismatch: literal is {ty:?}")))
            }
            Literal::Tuple(_) => Err(Error("to_vec on a tuple literal".into())),
        }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self {
            Literal::Tuple(v) => Ok(v),
            Literal::Array { .. } => Err(Error("to_tuple on an array literal".into())),
        }
    }
}

/// Parsed HLO module (text form). The stub only records the source path.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, Error> {
        // Parsing HLO text needs the real bindings; fail at compile time
        // of the entry, after the client already failed to come up.
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// PJRT client handle. Creation always fails in the stub build.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_f32_and_i32() {
        let v = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), v);
        assert!(lit.to_vec::<i32>().is_err(), "type mismatch must error");

        let w = [7i32, -9];
        let wb: Vec<u8> = w.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit2 =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2, 1], &wb).unwrap();
        assert_eq!(lit2.to_vec::<i32>().unwrap(), w);
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[4], &[0u8; 8])
                .is_err()
        );
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("PJRT unavailable"));
    }
}
