//! Simulated (paper-scale) experiments: Figs. 1, 4, 10-16.

use std::collections::HashMap;

use crate::baselines::{ablation_ladder, comparison_set};
use crate::config::serving::TransferKind;
use crate::config::{HardwareSpec, IterModel, ModelSpec, PrefillMode, ServingConfig};
use crate::engine::{drive_step, Backend, Engine, SimBackend, StageHints};
use crate::metrics::RunMetrics;
use crate::scheduler::{Batch, Phase, PrefillWork, Request, Scheduler};
use crate::sim::CostModel;
use crate::workload::{generate, WorkloadSpec};

use super::{f, render_table};

pub fn model_for(name: &str) -> ModelSpec {
    ModelSpec::by_name(name).unwrap_or_else(|| ModelSpec::lwm_7b())
}

fn workload_for(model: &ModelSpec, rate: f64, seed: u64) -> WorkloadSpec {
    if model.name == "llama3-8b" {
        WorkloadSpec::paper_llama3(rate, seed)
    } else {
        WorkloadSpec::paper_lwm(rate, seed)
    }
}

/// Serve a Poisson trace on the simulator; n scales with rate so every
/// run covers a comparable wall-clock window.
pub fn run_sim(cfg: ServingConfig, model: &ModelSpec, rate: f64, seed: u64) -> RunMetrics {
    let hw = HardwareSpec::a100_40gb();
    run_sim_dram(cfg, model, rate, seed, hw.dram_bytes)
}

/// [`run_sim`] with an explicit DRAM admission budget (the
/// admission-estimates measurement constrains it so reservations
/// actually bind).
pub fn run_sim_dram(
    cfg: ServingConfig,
    model: &ModelSpec,
    rate: f64,
    seed: u64,
    dram_bytes: usize,
) -> RunMetrics {
    let hw = HardwareSpec::a100_40gb();
    let n = ((rate * 240.0).ceil() as usize).clamp(16, 96);
    let backend = SimBackend::new(cfg.clone(), model.clone(), hw.clone());
    let sched =
        Scheduler::new(cfg, model.clone(), hw.hbm_kv_bytes).with_dram_capacity(dram_bytes);
    let engine = Engine::new(sched, Box::new(backend));
    let trace = generate(&workload_for(model, rate, seed), n, 0);
    engine.run_trace(trace, 3.0e4).unwrap().metrics
}

// ------------------------------------------------------------------ Fig. 1

/// Fixed-batch decode: throughput + KV blocks loaded per iteration.
/// (Prefetch off: Fig. 1 isolates the raw demand-load dynamics of
/// offloaded DSA without the rest of the SparseServe machinery.)
pub fn fig1_point(batch_size: usize, ctx: usize) -> (f64, f64) {
    let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
    cfg.ws_batch_control = false;
    cfg.r_max = 64;
    cfg.prefetch = false;
    let spec = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let mut b = SimBackend::new(cfg, spec, hw);
    let hints = StageHints::default();
    let mut requests = HashMap::new();
    for id in 0..batch_size as u32 {
        let mut r = Request::new(id, ctx, 1024, 0.0);
        r.phase = Phase::Prefill;
        b.register(&r).unwrap();
        requests.insert(id, r);
        let batch = Batch {
            decodes: vec![],
            prefill: Some(PrefillWork::Chunk { req: id, start: 0, len: ctx, is_last: true }),
        };
        drive_step(&mut b, &batch, &requests, &hints).unwrap();
        requests.get_mut(&id).unwrap().phase = Phase::Decode;
    }
    let batch = Batch { decodes: (0..batch_size as u32).collect(), prefill: None };
    for _ in 0..10 {
        drive_step(&mut b, &batch, &requests, &hints).unwrap();
    }
    let (mut time, mut loads, iters) = (0.0, 0usize, 40);
    for _ in 0..iters {
        let out = drive_step(&mut b, &batch, &requests, &hints).unwrap();
        time += out.iter_time_s;
        loads += out.blocks_loaded;
    }
    ((batch_size * iters) as f64 / time, loads as f64 / iters as f64)
}

pub fn fig1() -> String {
    let rows: Vec<Vec<String>> = [2usize, 4, 6, 8, 12, 16, 24, 32]
        .iter()
        .map(|&b| {
            let (thpt, loads) = fig1_point(b, 31_000);
            vec![b.to_string(), f(thpt), f(loads)]
        })
        .collect();
    render_table(
        "Fig 1: decode throughput & KV blocks loaded/iter vs batch size (LWM-7B, 31k ctx, no batch control)",
        &["batch", "tok/s", "blocks_loaded/iter"],
        &rows,
    )
}

// ------------------------------------------------------------------ Fig. 4

pub fn fig4() -> String {
    let hw = HardwareSpec::a100_40gb();
    let rows: Vec<Vec<String>> = [4usize, 8, 16, 32, 64]
        .iter()
        .map(|&kb| {
            let b = kb * 1024;
            vec![
                format!("{kb}KB"),
                f(hw.memcpy_bandwidth(b) / 1e9),
                f(hw.flash_h2d_bandwidth(b) / 1e9),
                f(hw.flash_d2h_bandwidth(b) / 1e9),
            ]
        })
        .collect();
    render_table(
        "Fig 4: PCIe effective bandwidth (GB/s) vs block size",
        &["block", "memcpy", "FlashH2D", "FlashD2H"],
        &rows,
    )
}

// ------------------------------------------------------------- Figs. 10-12

/// Default rate sweeps per model: GQA shrinks Llama3's KV 4x, so every
/// system saturates later — the paper likewise sweeps Llama3 to higher
/// rates than LWM.
pub fn default_rates(model_name: &str) -> Vec<f64> {
    if model_name == "llama3-8b" {
        vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]
    } else {
        vec![0.05, 0.1, 0.15, 0.2, 0.25, 0.3]
    }
}

pub fn fig10_11_12(model_name: &str, rates: &[f64]) -> String {
    let model = model_for(model_name);
    let systems = comparison_set(2048, 2048, model.n_layers);
    let mut rows = Vec::new();
    for &rate in rates {
        for preset in &systems {
            // paper caps vLLM-SO / vLLM at rates where they still terminate
            let m = run_sim(preset.cfg.clone(), &model, rate, 11);
            rows.push(vec![
                format!("{rate}"),
                preset.name.to_string(),
                f(m.ttft.mean()),
                f(m.throughput()),
                f(m.tbt.mean()),
                f(m.queue_delay.mean()),
            ]);
        }
    }
    render_table(
        &format!("Figs 10-12: TTFT / throughput / TBT vs request rate ({model_name})"),
        &["rate", "system", "mean_TTFT_s", "tok/s", "mean_TBT_s", "queue_s"],
        &rows,
    )
}

// ----------------------------------------------------------------- Fig. 13

/// Goodput: max request rate satisfying the paper's SLO — P99 TBT <= 25x
/// "the execution time of a decoding iteration" (interpreted per-system:
/// the run's own mean decode-iteration time, so slower-but-batchier
/// systems are judged against their own iteration, as in Sarathi-Serve's
/// SLO definition the paper cites) AND mean queueing delay <= 2 s.
pub fn goodput(cfg: &ServingConfig, model: &ModelSpec) -> f64 {
    let rates = [
        0.025, 0.05, 0.075, 0.1, 0.125, 0.15, 0.2, 0.25, 0.3, 0.35, 0.4, 0.5, 0.6, 0.8,
    ];
    let mut best = 0.0;
    for &rate in &rates {
        let m = run_sim(cfg.clone(), model, rate, 13);
        let ref_iter = m.iter_time.mean().max(1e-6);
        let finished_enough = m.requests_finished * 10 >= m.ttft.len() * 8;
        if finished_enough && m.meets_slo(ref_iter, cfg.slo_tbt_factor, cfg.slo_queue_delay_s) {
            best = rate;
        } else if rate > best + 0.16 {
            break; // well past the knee
        }
    }
    best
}

pub fn fig13(model_name: &str) -> String {
    let model = model_for(model_name);
    let ladder = ablation_ladder(2048, 2048, model.n_layers);
    let mut rows = Vec::new();
    let mut prev = 0.0;
    for preset in &ladder {
        let g = goodput(&preset.cfg, &model);
        let gain = if prev > 0.0 { g / prev } else { 1.0 };
        rows.push(vec![preset.name.to_string(), f(g), format!("{gain:.2}x")]);
        prev = g.max(1e-9);
    }
    render_table(
        &format!("Fig 13: goodput ablation ladder ({model_name}; SLO p99 TBT<=25x iter, queue<=2s)"),
        &["system", "goodput_rps", "step_gain"],
        &rows,
    )
}

// ----------------------------------------------------------------- Fig. 14

pub fn fig14a() -> String {
    let spec = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let cost = CostModel::new(spec.clone(), hw);
    let mut rows = Vec::new();
    for &batch in &[2usize, 4, 8, 16] {
        // steady-state miss volume per iteration at this batch size
        // (measured from the Fig. 1 harness with flash transfers)
        let (_, loads) = fig1_point(batch, 31_000);
        let n = loads.round() as usize;
        let compute = cost.decode_iter_time(batch, &vec![2048; batch]);
        let memcpy = cost.load_time(TransferKind::Memcpy, n);
        let flash = cost.load_time(TransferKind::Flash, n);
        rows.push(vec![
            batch.to_string(),
            f((compute + memcpy) * 1e3),
            f(memcpy * 1e3),
            f((compute + flash) * 1e3),
            f(flash * 1e3),
            format!("{:.1}%", 100.0 * memcpy / (compute + memcpy)),
            format!("{:.2}x", memcpy / flash.max(1e-12)),
        ]);
    }
    render_table(
        "Fig 14a: decode batch latency & KV loading latency (ms), memcpy vs FlashH2D",
        &["batch", "memcpy_batch", "memcpy_load", "flash_batch", "flash_load", "load_share", "speedup"],
        &rows,
    )
}

pub fn fig14b() -> String {
    let spec = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let cost = CostModel::new(spec, hw);
    let rows = vec![
        vec!["memcpy-based".into(), f(cost.save_overhead_factor(TransferKind::Memcpy, true))],
        vec![
            "GPU-direct".into(),
            f(cost.save_overhead_factor(TransferKind::GpuDirectSave, true)),
        ],
        vec!["FlashD2H".into(), f(cost.save_overhead_factor(TransferKind::Flash, true))],
    ];
    render_table(
        "Fig 14b: prefill latency normalized to standalone prefill compute",
        &["saving method", "normalized latency"],
        &rows,
    )
}

// ----------------------------------------------------------------- Fig. 15

pub fn fig15(rates: &[f64]) -> String {
    let model = ModelSpec::lwm_7b();
    let mut with = ServingConfig::sparseserve(2048, 2048, 32);
    with.r_max = 64;
    let mut without = with.clone();
    without.ws_batch_control = false;
    let mut rows = Vec::new();
    for &rate in rates {
        let m_w = run_sim(with.clone(), &model, rate, 11);
        let m_wo = run_sim(without.clone(), &model, rate, 11);
        rows.push(vec![
            format!("{rate}"),
            f(m_w.throughput()),
            f(m_wo.throughput()),
            f(m_w.blocks_loaded_per_iter.mean()),
            f(m_wo.blocks_loaded_per_iter.mean()),
        ]);
    }
    render_table(
        "Fig 15: throughput & KV loads/iter, with vs without working-set batch control (LWM-7B)",
        &["rate", "tok/s_WC", "tok/s_noWC", "loads_WC", "loads_noWC"],
        &rows,
    )
}

// ------------------------------------------------- Prefetch ablation (PF)

/// Run the working-set prefetch ablation at one rate: the full system
/// vs the identical config with prefetching off (equal workload, same
/// seed). Returns `(prefetch_on, prefetch_off)` metrics.
pub fn prefetch_ablation_metrics(rate: f64, seed: u64) -> (RunMetrics, RunMetrics) {
    let model = ModelSpec::lwm_7b();
    let pair = crate::baselines::prefetch_ablation(2048, 2048, model.n_layers);
    let on = run_sim(pair[0].cfg.clone(), &model, rate, seed);
    let off = run_sim(pair[1].cfg.clone(), &model, rate, seed);
    (on, off)
}

/// Run the iteration-model comparison at one rate: the identical full
/// system timed with the per-layer event model vs the coarse two-stream
/// model (equal workload, same seed). Returns `(per_layer, coarse)`
/// metrics (the `bench` subcommand emits `BENCH_layer_model.json` from
/// these numbers).
pub fn layer_model_metrics(rate: f64, seed: u64) -> (RunMetrics, RunMetrics) {
    let model = ModelSpec::lwm_7b();
    let mut per = ServingConfig::sparseserve(2048, 2048, model.n_layers);
    per.iter_model = IterModel::PerLayer;
    let mut coarse = per.clone();
    coarse.iter_model = IterModel::Coarse;
    let p = run_sim(per, &model, rate, seed);
    let c = run_sim(coarse, &model, rate, seed);
    (p, c)
}

/// Sweep the selection layer-skew knob at one rate on the no-prefetch
/// system (pure demand traffic, so miss-discovery timing is the only
/// thing that moves): the same workload with miss churn concentrated in
/// early layers (skew -1), uniform (0) and late layers (+1). The tilt
/// preserves aggregate churn, so the runs move comparable traffic —
/// only WHERE misses are discovered changes, and with it how much of
/// the loading the per-layer event model can hide. Returns
/// `(skew, metrics)` per point (the `bench` subcommand folds these into
/// `BENCH_layer_model.json`).
pub fn layer_skew_metrics(rate: f64, seed: u64) -> Vec<(f64, RunMetrics)> {
    let model = ModelSpec::lwm_7b();
    [-1.0, 0.0, 1.0]
        .into_iter()
        .map(|skew| {
            let mut cfg = ServingConfig::sparseserve_np(2048, 2048, model.n_layers);
            cfg.sim_layer_skew = skew;
            (skew, run_sim(cfg, &model, rate, seed))
        })
        .collect()
}

/// Layer-skew table: stall/iteration vs the miss-discovery tilt.
pub fn fig_layer_skew(rates: &[f64]) -> String {
    let mut rows = Vec::new();
    for &rate in rates {
        for (skew, m) in layer_skew_metrics(rate, 11) {
            rows.push(vec![
                format!("{rate}"),
                format!("{skew}"),
                f(m.iter_time.mean() * 1e3),
                f(m.stall_time.mean() * 1e3),
                f(m.hidden_time.mean() * 1e3),
                f(m.blocks_loaded_per_iter.mean()),
            ]);
        }
    }
    render_table(
        "Layer skew: mean iteration/stall time (ms) vs miss-discovery tilt (LWM-7B, no prefetch)",
        &["rate", "skew", "iter_ms", "stall_ms", "hidden_ms", "loads/iter"],
        &rows,
    )
}

/// Measure the admission-estimates knob on the simulate path (the serve
/// path shares the identical `Scheduler` logic): the full system with
/// estimate-based reservations (the `sparseserve` default) vs the same
/// config with conservative full-lifetime reservations, under a DRAM
/// budget tight enough that admission binds. Returns `(on, off)`
/// metrics; the `bench` subcommand prints them and folds the headline
/// numbers into `BENCH_hotpath.json`.
pub fn admission_estimates_metrics(rate: f64, seed: u64) -> (RunMetrics, RunMetrics) {
    let model = ModelSpec::lwm_7b();
    let on = ServingConfig::sparseserve(2048, 2048, model.n_layers);
    let mut off = on.clone();
    off.admission_estimates = false;
    // DRAM sized to ~6 full-lifetime reservations of the mean workload
    // shape: conservative admission leaves real headroom on the table
    let sizer = Scheduler::new(on.clone(), model.clone(), 0);
    let dram = 6 * sizer.full_kv_bytes(24_000, 1024);
    let m_on = run_sim_dram(on, &model, rate, seed, dram);
    let m_off = run_sim_dram(off, &model, rate, seed, dram);
    (m_on, m_off)
}

/// Iteration-model table: per-layer vs coarse stall/iteration means.
pub fn fig_layer_model(rates: &[f64]) -> String {
    let mut rows = Vec::new();
    for &rate in rates {
        let (p, c) = layer_model_metrics(rate, 11);
        rows.push(vec![
            format!("{rate}"),
            f(p.iter_time.mean() * 1e3),
            f(c.iter_time.mean() * 1e3),
            f(p.stall_time.mean() * 1e3),
            f(c.stall_time.mean() * 1e3),
            f(p.hidden_time.mean() * 1e3),
        ]);
    }
    render_table(
        "Iteration model: mean iteration & stall time (ms), per-layer vs coarse (LWM-7B)",
        &["rate", "iter_layered", "iter_coarse", "stall_layered", "stall_coarse", "hidden_ms"],
        &rows,
    )
}

/// Prefetch ablation table: iteration/stall time with the prefetcher on
/// vs off, plus the staged-block hit rate and waste (the `bench`
/// subcommand emits the same numbers as `BENCH_prefetch.json`).
pub fn fig_prefetch(rates: &[f64]) -> String {
    let mut rows = Vec::new();
    for &rate in rates {
        let (on, off) = prefetch_ablation_metrics(rate, 11);
        rows.push(vec![
            format!("{rate}"),
            f(on.iter_time.mean() * 1e3),
            f(off.iter_time.mean() * 1e3),
            f(on.stall_time.mean() * 1e3),
            f(off.stall_time.mean() * 1e3),
            format!("{:.0}%", 100.0 * on.prefetch_hit_rate()),
            on.prefetch_wasted.to_string(),
        ]);
    }
    render_table(
        "Prefetch ablation: mean iteration & stall time (ms), prefetch on vs off (LWM-7B)",
        &["rate", "iter_on", "iter_off", "stall_on", "stall_off", "pf_hit", "pf_wasted"],
        &rows,
    )
}

// -------------------------------------------- Prefix sharing ablation (PFX)

/// Headline numbers for one side of the prefix-sharing measurement:
/// latency plus the byte traffic the run actually paid.
///
/// * `prefill_compute_s` — modeled prefill seconds the run paid,
///   summed per request from [`CostModel::prefill_time_suffix`] over
///   the suffix each request actually prefilled (`prompt_len -
///   prefix_matched`); with sharing off every suffix is the full
///   prompt.
/// * `hbm_in_bytes` — everything HBM ingested from DRAM: demand PCIe
///   traffic (`LayerProfile::bytes_moved`) plus prefetch staging.
/// * `dram_written_bytes` — KV written to the DRAM tier (prefilled
///   suffix + generated tokens, at the model's per-token KV cost);
///   adopted prefixes write nothing — sharers reuse the pool's blocks.
#[derive(Debug, Clone)]
pub struct PrefixSharingPoint {
    pub ttft_mean_s: f64,
    pub prefill_compute_s: f64,
    pub hbm_in_bytes: u64,
    pub dram_written_bytes: u64,
    pub prefix_hits: u64,
    pub prefix_matched_tokens: u64,
    pub tokens_generated: usize,
    pub requests_finished: usize,
}

fn prefix_point(cfg: ServingConfig, model: &ModelSpec, trace: Vec<Request>) -> PrefixSharingPoint {
    let hw = HardwareSpec::a100_40gb();
    let backend = SimBackend::new(cfg.clone(), model.clone(), hw.clone());
    let sched = Scheduler::new(cfg, model.clone(), hw.hbm_kv_bytes);
    let report = Engine::new(sched, Box::new(backend)).run_trace(trace, 3.0e4).unwrap();
    let cost = CostModel::new(model.clone(), hw);
    let kv_token_bytes = model.kv_bytes_per_token() as u64;
    let mut prefill_compute_s = 0.0;
    let mut dram_written_bytes = report.metrics.tokens_generated as u64 * kv_token_bytes;
    for r in report.requests.values() {
        let plen = r.prompt_len;
        let suffix = plen.saturating_sub(r.prefix_matched);
        prefill_compute_s += cost.prefill_time_suffix(plen, r.prefix_matched, plen.max(1));
        dram_written_bytes += suffix as u64 * kv_token_bytes;
    }
    let demand_bytes: u64 = report.metrics.layer_profile.bytes_moved.iter().sum();
    let staged_bytes = report.metrics.prefetch_blocks * model.block_bytes() as u64;
    PrefixSharingPoint {
        ttft_mean_s: report.metrics.ttft.mean(),
        prefill_compute_s,
        hbm_in_bytes: demand_bytes + staged_bytes,
        dram_written_bytes,
        prefix_hits: report.metrics.prefix_hits,
        prefix_matched_tokens: report.metrics.prefix_matched_tokens,
        tokens_generated: report.metrics.tokens_generated,
        requests_finished: report.metrics.requests_finished,
    }
}

/// Run the prefix-sharing ablation at one pool hit rate: an identical
/// token-filled trace (4 shared 4096-token system prompts, `hit_frac`
/// of requests opening with one) served with the prefix index on vs
/// off. Both runs see the exact same requests — only block ownership
/// changes. Returns `(sharing_on, sharing_off)` points (the `bench`
/// subcommand emits `BENCH_prefix.json` from these numbers).
pub fn prefix_sharing_metrics(
    rate: f64,
    hit_frac: f64,
    seed: u64,
) -> (PrefixSharingPoint, PrefixSharingPoint) {
    let model = ModelSpec::lwm_7b();
    let n = ((rate * 240.0).ceil() as usize).clamp(16, 96);
    let wl = WorkloadSpec::paper_lwm(rate, seed).with_prefix_pools(4, 4096, hit_frac);
    let trace = generate(&wl, n, 0);
    let mut on = ServingConfig::sparseserve(2048, 2048, model.n_layers);
    on.prefix_sharing = true;
    let mut off = on.clone();
    off.prefix_sharing = false;
    let p_on = prefix_point(on, &model, trace.clone());
    let p_off = prefix_point(off, &model, trace);
    (p_on, p_off)
}

/// Prefix-sharing table: TTFT, modeled prefill compute and byte
/// traffic, sharing on vs off across pool hit rates.
pub fn fig_prefix(rates: &[f64]) -> String {
    let mut rows = Vec::new();
    for &rate in rates {
        for hit in [0.0, 0.3, 0.7] {
            let (on, off) = prefix_sharing_metrics(rate, hit, 11);
            rows.push(vec![
                format!("{rate}"),
                format!("{hit}"),
                f(on.ttft_mean_s),
                f(off.ttft_mean_s),
                f(on.prefill_compute_s),
                f(off.prefill_compute_s),
                f(on.hbm_in_bytes as f64 / 1e9),
                f(off.hbm_in_bytes as f64 / 1e9),
                f(on.dram_written_bytes as f64 / 1e9),
                f(off.dram_written_bytes as f64 / 1e9),
                on.prefix_hits.to_string(),
            ]);
        }
    }
    render_table(
        "Prefix sharing: TTFT (s), prefill compute (s) and HBM/DRAM traffic (GB), sharing on vs off (LWM-7B)",
        &[
            "rate", "hit", "ttft_on", "ttft_off", "pf_on", "pf_off", "hbm_on", "hbm_off",
            "dram_on", "dram_off", "hits",
        ],
        &rows,
    )
}

// ----------------------------------------------------------------- Fig. 16

pub fn fig16a(rates: &[f64]) -> String {
    let model = ModelSpec::lwm_7b();
    let ls = ServingConfig::sparseserve(2048, 2048, 32);
    let mut chunked = ls.clone();
    chunked.prefill_mode = PrefillMode::Chunked;
    let mut rows = Vec::new();
    for &rate in rates {
        let m_ls = run_sim(ls.clone(), &model, rate, 11);
        let m_ch = run_sim(chunked.clone(), &model, rate, 11);
        rows.push(vec![
            format!("{rate}"),
            f(m_ch.ttft.mean()),
            f(m_ls.ttft.mean()),
            format!("{:.2}x", m_ch.ttft.mean() / m_ls.ttft.mean().max(1e-9)),
        ]);
    }
    render_table(
        "Fig 16a: mean TTFT, chunked vs layer-segmented prefill (LWM-7B)",
        &["rate", "chunked_s", "layer_seg_s", "reduction"],
        &rows,
    )
}

pub fn fig16b() -> String {
    let model = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let cost = CostModel::new(model, hw);
    let prompt = 16_384;
    let plain = cost.prefill_time_plain(prompt);
    let rows: Vec<Vec<String>> = [512usize, 1024, 2048, 4096]
        .iter()
        .map(|&c| {
            vec![
                c.to_string(),
                format!("{:.2}x", cost.prefill_time_chunked(prompt, c) / plain),
                "1.00x".into(), // layer-segmented == plain per-token compute
            ]
        })
        .collect();
    render_table(
        "Fig 16b: prefill attention overhead vs plain prefill (16k prompt)",
        &["chunk", "chunked", "layer-segmented"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_table_renders() {
        let t = fig4();
        assert!(t.contains("FlashH2D"));
        assert!(t.lines().count() >= 7);
    }

    #[test]
    fn fig16b_monotone() {
        let t = fig16b();
        assert!(t.contains("512"));
    }

    #[test]
    fn fig14b_values() {
        let t = fig14b();
        assert!(t.contains("1.76"));
        assert!(t.contains("1.28"));
    }

    /// The tentpole's acceptance bar: on a warm-prefix workload the
    /// sharing run must pay strictly less TTFT, prefill compute and
    /// HBM/DRAM byte traffic than the exclusive-ownership run over the
    /// SAME trace — at equal generated output.
    #[test]
    fn prefix_sharing_strictly_wins_on_warm_traffic() {
        let (on, off) = prefix_sharing_metrics(0.05, 0.7, 11);
        assert_eq!(on.tokens_generated, off.tokens_generated, "equal output");
        assert_eq!(on.requests_finished, off.requests_finished);
        assert!(on.prefix_hits > 0, "pools must produce index hits");
        assert!(on.prefix_matched_tokens > 0);
        assert!(
            on.ttft_mean_s < off.ttft_mean_s,
            "TTFT: {} !< {}",
            on.ttft_mean_s,
            off.ttft_mean_s
        );
        assert!(
            on.prefill_compute_s < off.prefill_compute_s,
            "prefill: {} !< {}",
            on.prefill_compute_s,
            off.prefill_compute_s
        );
        assert!(
            on.hbm_in_bytes < off.hbm_in_bytes,
            "HBM bytes: {} !< {}",
            on.hbm_in_bytes,
            off.hbm_in_bytes
        );
        assert!(
            on.dram_written_bytes < off.dram_written_bytes,
            "DRAM bytes: {} !< {}",
            on.dram_written_bytes,
            off.dram_written_bytes
        );
    }

    /// With zero pool hits every prompt is unique: the index never
    /// matches, and the sharing run must be indistinguishable from the
    /// exclusive run on the same trace.
    #[test]
    fn prefix_sharing_at_zero_hit_rate_changes_nothing() {
        let (on, off) = prefix_sharing_metrics(0.05, 0.0, 11);
        assert_eq!(on.prefix_hits, 0);
        assert_eq!(on.prefix_matched_tokens, 0);
        assert_eq!(on.tokens_generated, off.tokens_generated);
        assert_eq!(on.requests_finished, off.requests_finished);
        assert_eq!(on.ttft_mean_s, off.ttft_mean_s, "bit-identical TTFT");
        assert_eq!(on.prefill_compute_s, off.prefill_compute_s);
        assert_eq!(on.hbm_in_bytes, off.hbm_in_bytes);
        assert_eq!(on.dram_written_bytes, off.dram_written_bytes);
    }
}
