//! Experiment harnesses: one function per paper table/figure.
//!
//! Shared by `examples/paper_figures.rs` and the `cargo bench` targets so
//! the numbers in EXPERIMENTS.md always come from one code path.
//! Simulated experiments run at paper scale (LWM-7B / Llama3-8B on the
//! A100 testbed substitute); `real` experiments execute the tiny-llm
//! artifacts on PJRT.

pub mod cluster_exp;
pub mod hotpath;
pub mod real;
pub mod sim_exp;

pub use cluster_exp::{
    cluster_skew_metrics, cluster_trace, fig_cluster, run_cluster_variant, ClusterVariant,
};
pub use hotpath::{full_step_results, hotpath_doc};
pub use real::{fig8_overlap, table1_accuracy};
pub use sim_exp::*;

/// Render a simple aligned table.
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = format!("== {title} ==\n");
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let header_cells: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&header_cells, &widths));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

pub fn f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 100.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            "t",
            &["a", "long_header"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("== t =="));
        assert!(t.contains("long_header"));
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].len(), lines[2].len());
    }
}
