//! Cluster serving experiments: goodput vs tenant skew for one engine,
//! N engines without migration, and N engines with KV migration.
//!
//! The testbed is deliberately heterogeneous — the shape that makes
//! migration matter:
//!
//! - engine 0 ("capacity engine"): a deep DRAM pool behind a small HBM
//!   working-set cache (the tests/engine_core.rs eviction recipe: 40
//!   band-group slots). It admits nearly everything and is where
//!   memory-exhaustion victims appear.
//! - engine 1 ("spill engine"): a full-size HBM working-set cache
//!   behind a shallow DRAM pool (~4 largest-request reservations). The
//!   router can only place a few requests here, but its HBM headroom
//!   makes it the natural migration target.
//!
//! Under skewed multi-tenant arrivals the hot tenant's stretched
//! prompts pile onto engine 0, its HBM thrashes, and the three
//! variants separate: single-engine and no-migration clusters evict
//! the victims; the migrating cluster drains them to engine 1 and
//! finishes them. `bench --out-cluster` folds these numbers into
//! `BENCH_cluster.json`.

use crate::cluster::{ClusterConfig, ClusterReport, ClusterServer};
use crate::config::{HardwareSpec, ModelSpec, ServingConfig};
use crate::engine::{EngineCore, SimBackend};
use crate::scheduler::{Request, Scheduler};
use crate::sim::CostModel;
use crate::workload::{generate, WorkloadSpec};

use super::{f, render_table};

/// The three systems the cluster experiment compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterVariant {
    /// One capacity engine serving the whole trace.
    Single,
    /// Capacity + spill engine, victims evicted (no migration).
    ScaleOut,
    /// Capacity + spill engine with typed KV migration.
    ScaleOutMigrate,
}

impl ClusterVariant {
    pub const ALL: [ClusterVariant; 3] =
        [ClusterVariant::Single, ClusterVariant::ScaleOut, ClusterVariant::ScaleOutMigrate];

    pub fn name(self) -> &'static str {
        match self {
            ClusterVariant::Single => "1-engine",
            ClusterVariant::ScaleOut => "2-engine",
            ClusterVariant::ScaleOutMigrate => "2-engine+migration",
        }
    }
}

/// Serving policy shared by every engine in the experiment: the
/// eviction recipe (no working-set batch control, pure demand traffic)
/// so HBM pressure surfaces as typed victims instead of being planned
/// around.
fn cluster_cfg() -> ServingConfig {
    let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
    cfg.ws_batch_control = false;
    cfg.prefetch = false;
    cfg
}

/// Engine 0: deep DRAM, 40-band-group HBM (three 64-group decodes
/// cannot coexist).
fn capacity_engine() -> EngineCore {
    let cfg = cluster_cfg();
    let spec = ModelSpec::lwm_7b();
    let mut hw = HardwareSpec::a100_40gb();
    hw.hbm_kv_bytes = 40 * spec.n_layers * spec.n_kv_heads * spec.block_bytes();
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
    // honest HBM capacity: the router reads `m_avl` off this scheduler
    let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes).with_dram_capacity(1 << 40);
    EngineCore::new(sched, Box::new(backend))
}

/// Engine 1: full-size HBM, DRAM sized to ~4 largest reservations —
/// shallow enough that the router's watermark caps fresh placements at
/// a handful of requests, deep enough that the 15% reserve above the
/// watermark can hold a drained mid-size victim.
fn spill_engine() -> EngineCore {
    let cfg = cluster_cfg();
    let spec = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
    let sizer = Scheduler::new(cfg.clone(), spec.clone(), hw.hbm_kv_bytes);
    let dram = 4 * sizer.full_kv_bytes(32_768, 64);
    let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes).with_dram_capacity(dram);
    EngineCore::new(sched, Box::new(backend))
}

/// The skewed multi-tenant trace every variant replays: 4 tenants, the
/// hot one stretched by `skew`, outputs capped short so goodput
/// differences come from admission/eviction dynamics rather than
/// decode tails.
pub fn cluster_trace(skew: f64, seed: u64, n: usize) -> Vec<Request> {
    let mut spec = WorkloadSpec::paper_lwm(0.25, seed).with_tenant_skew(4, skew);
    spec.max_output = 64;
    generate(&spec, n, 0)
}

/// Run one variant over a trace on the shared cluster clock.
pub fn run_cluster_variant(variant: ClusterVariant, trace: Vec<Request>) -> ClusterReport {
    let spec = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let cost = CostModel::new(spec, hw);
    let engines = match variant {
        ClusterVariant::Single => vec![capacity_engine()],
        _ => vec![capacity_engine(), spill_engine()],
    };
    let cfg = ClusterConfig {
        migrate: variant == ClusterVariant::ScaleOutMigrate,
        ..ClusterConfig::default()
    };
    ClusterServer::new(engines, cost, cfg)
        .run_trace(trace, 1e5)
        .expect("cluster trace replay")
}

/// One goodput-vs-skew point: the three variants on the same trace.
pub fn cluster_skew_metrics(skew: f64, seed: u64) -> Vec<(&'static str, ClusterReport)> {
    ClusterVariant::ALL
        .iter()
        .map(|&v| (v.name(), run_cluster_variant(v, cluster_trace(skew, seed, 14))))
        .collect()
}

/// Cluster table: goodput / finished / evicted / migrated vs skew.
pub fn fig_cluster(skews: &[f64]) -> String {
    let mut rows = Vec::new();
    for &skew in skews {
        for (name, rep) in cluster_skew_metrics(skew, 7) {
            rows.push(vec![
                format!("{skew}"),
                name.to_string(),
                f(rep.goodput_rps() * 1e3),
                rep.requests_finished().to_string(),
                rep.requests_evicted().to_string(),
                rep.requests_migrated().to_string(),
                f(rep.migration_transfer_s()),
            ]);
        }
    }
    render_table(
        "Cluster: goodput (finishes/ks) vs tenant skew — 1 engine vs 2 engines ± KV migration",
        &["skew", "system", "goodput", "finished", "evicted", "migrated", "transfer_s"],
        &rows,
    )
}
