//! Full-step hot-path microbench (ISSUE 4 acceptance; DESIGN.md §Perf).
//!
//! The original `benches/hotpath.rs` timed isolated L3 operations
//! (top-k, LRU ops, scheduler plan). This module benches the *whole*
//! step pipeline the zero-clone refactor targets — steady-state
//! `EngineCore::step` on the `SimBackend`: plan → stage → per-layer
//! decode → commit — plus a hybrid (prefill + decodes) step and a
//! rollback+retry step (typed `HbmExhausted`, evict, same-iteration
//! redo). The `bench` subcommand emits the numbers as
//! `BENCH_hotpath.json`, uploaded by CI so the per-iteration overhead
//! trajectory is tracked PR-over-PR.

use std::collections::BTreeMap;

use crate::config::{HardwareSpec, ModelSpec, ServingConfig};
use crate::engine::{EngineCore, SimBackend, SubmitRequest};
use crate::scheduler::Scheduler;
use crate::util::bench::{bench, BenchResult};
use crate::util::json::Value;
use crate::util::stats::secs_to_us;

/// An engine with `n` long-lived decodes in steady state (LWM-7B, full
/// SparseServe config) and the serving clock it reached.
fn decode_core(n: usize) -> (EngineCore, f64) {
    decode_core_at_depth(n, 1)
}

/// Same steady-decode engine at an explicit executor pipeline depth
/// (1 = synchronous plan→stage→compute, 2 = N+1's plan/stage staged
/// under N's compute).
fn decode_core_at_depth(n: usize, depth: usize) -> (EngineCore, f64) {
    let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
    cfg.pipeline_depth = depth;
    let spec = ModelSpec::lwm_7b();
    let hw = HardwareSpec::a100_40gb();
    let backend = SimBackend::new(cfg.clone(), spec.clone(), hw.clone());
    // DRAM admission left unbounded: the effectively-infinite `max_new`
    // below would otherwise reserve more than any real DRAM budget
    let sched = Scheduler::new(cfg, spec, hw.hbm_kv_bytes);
    let mut core = EngineCore::new(sched, Box::new(backend)).retain_finished(false);
    for _ in 0..n {
        // effectively infinite completions: the bench loop never drains
        core.submit(SubmitRequest::synthetic(16_000).max_new(1_000_000), 0.0)
            .expect("bench submit");
    }
    let mut now = 0.0;
    let mut steps = 0;
    while core.sched().decoding().len() < n {
        steps += 1;
        assert!(steps < 10_000, "bench setup stalled before steady state");
        let out = core.step(now).expect("bench setup step");
        now += out.iter_time_s.max(1e-6);
    }
    // a few steady iterations warm every recycled scratch buffer
    for _ in 0..5 {
        let out = core.step(now).expect("bench warm step");
        now += out.iter_time_s.max(1e-6);
    }
    (core, now)
}

/// Run the full-step microbench suite. `budget_s` is the wall-clock
/// budget per case (the CI gate uses a small budget; `cargo bench
/// --bench hotpath` a larger one). Panics on any engine error — the CI
/// job fails if the full-step pipeline breaks.
pub fn full_step_results(budget_s: f64) -> Vec<BenchResult> {
    let mut results = Vec::new();

    // ---- steady-state decode step: plan → stage → 32 layers → commit ----
    {
        let (mut core, mut now) = decode_core(8);
        results.push(bench(
            "fullstep/decode B=8 (plan+stage+layers+commit)",
            budget_s,
            5,
            || {
                let out = core.step(now).expect("decode step");
                debug_assert!(out.ran_batch);
                now += out.iter_time_s.max(1e-6);
            },
        ));
    }

    // ---- pipelined steady-state decode: same batch shape as the row
    // above at pipeline_depth 2, so the pair reads as a direct depth-1
    // vs depth-2 comparison. Besides p50, the point reports how much
    // modeled plan/stage time the overlap hid per iteration. ----
    {
        let (mut core, mut now) = decode_core_at_depth(8, 2);
        let hidden_before = core.metrics().plan_stage_hidden_s;
        let iters_before = core.metrics().iterations;
        let r = bench(
            "fullstep/pipelined B=8 (depth-2 plan/stage overlap)",
            budget_s,
            5,
            || {
                let out = core.step(now).expect("pipelined step");
                debug_assert!(out.ran_batch);
                now += out.iter_time_s.max(1e-6);
            },
        );
        let hidden = core.metrics().plan_stage_hidden_s - hidden_before;
        let iters = (core.metrics().iterations - iters_before).max(1);
        results.push(
            r.with_extra("plan_stage_hidden_s", hidden)
                .with_extra("plan_stage_hidden_us_per_iter", secs_to_us(hidden / iters as f64)),
        );
    }

    // ---- hybrid step: a layer-segmented prefill rides along ----
    {
        let (mut core, mut now) = decode_core(8);
        results.push(bench(
            "fullstep/hybrid (prefill segment + 8 decodes)",
            budget_s,
            5,
            || {
                if core.sched().prefilling_id().is_none() {
                    // keep a prefill in flight; max_new(1) finishes it the
                    // moment the first token emits, so the decode pool
                    // stays at 8
                    core.submit(SubmitRequest::synthetic(8_000).max_new(1), now)
                        .expect("hybrid submit");
                }
                let out = core.step(now).expect("hybrid step");
                now += out.iter_time_s.max(1e-6);
            },
        ));
    }

    // ---- rollback + retry: typed HbmExhausted, evict, same-iteration redo ----
    {
        let mut cfg = ServingConfig::sparseserve(2048, 2048, 32);
        cfg.ws_batch_control = false; // let the doomed prefill into the batch
        let spec = ModelSpec::lwm_7b();
        let mut hw = HardwareSpec::a100_40gb();
        // HBM so small that ONE whale layer segment cannot fit, yet
        // large enough that the four 1k-prompt decodes' per-band working
        // sets stay resident (decode is mid-phase fallible now: too
        // little HBM would evict the steady decodes instead of the whale)
        hw.hbm_kv_bytes = 80 * spec.n_layers * spec.n_kv_heads * spec.block_bytes();
        let backend = SimBackend::new(cfg.clone(), spec.clone(), hw);
        let sched = Scheduler::new(cfg, spec, 1 << 40);
        let mut core = EngineCore::new(sched, Box::new(backend)).retain_finished(false);
        for _ in 0..4 {
            core.submit(SubmitRequest::synthetic(1_024).max_new(1_000_000), 0.0)
                .expect("bench submit");
        }
        let mut now = 0.0;
        let mut steps = 0;
        while core.sched().decoding().len() < 4 {
            steps += 1;
            assert!(steps < 10_000, "rollback-bench setup stalled");
            let out = core.step(now).expect("rollback-bench setup");
            now += out.iter_time_s.max(1e-6);
        }
        results.push(bench(
            "fullstep/rollback+retry (evict + same-iteration redo)",
            budget_s,
            2,
            || {
                // a whale whose first layer segment trips the single-layer
                // HBM bound: the step rolls back, evicts it and retries
                // the surviving decodes in the same iteration
                let whale = core
                    .submit(SubmitRequest::synthetic(100_000).max_new(4), now)
                    .expect("whale submit");
                let out = core.step(now).expect("rollback step");
                debug_assert!(out.evicted.iter().any(|(id, _)| *id == whale));
                debug_assert!(out.ran_batch, "survivors must still commit");
                now += out.iter_time_s.max(1e-6);
            },
        ));
    }

    results
}

/// `BENCH_hotpath.json` document for a result set.
pub fn hotpath_doc(results: &[BenchResult]) -> Value {
    let points = results
        .iter()
        .map(|r| {
            let mut p = BTreeMap::new();
            p.insert("name".into(), Value::Str(r.name.clone()));
            p.insert("mean_us".into(), Value::Num(secs_to_us(r.mean_s)));
            p.insert("p50_us".into(), Value::Num(secs_to_us(r.p50_s)));
            p.insert("p99_us".into(), Value::Num(secs_to_us(r.p99_s)));
            p.insert("iters".into(), Value::Num(r.iters as f64));
            for (key, value) in &r.extra {
                p.insert(key.clone(), Value::Num(*value));
            }
            Value::Obj(p)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Value::Str("hotpath_full_step".into()));
    doc.insert("model".into(), Value::Str("lwm-7b".into()));
    doc.insert("points".into(), Value::Arr(points));
    Value::Obj(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_step_bench_smoke() {
        // tiny budget: exercises all four cases end-to-end (the CI gate
        // runs the same suite via `bench` and fails the job on panic)
        let results = full_step_results(0.01);
        assert_eq!(results.len(), 4);
        for r in &results {
            assert!(r.iters >= 10, "{} ran {} iters", r.name, r.iters);
            assert!(r.mean_s >= 0.0 && r.p99_s >= r.p50_s);
        }
        // the depth-2 row carries its overlap side-metric and actually
        // hid plan/stage time in steady decode
        let piped = results
            .iter()
            .find(|r| r.name.starts_with("fullstep/pipelined"))
            .expect("pipelined row");
        let hidden = piped
            .extra
            .iter()
            .find(|(k, _)| k == "plan_stage_hidden_s")
            .map(|(_, v)| *v)
            .expect("hidden side-metric");
        assert!(hidden > 0.0, "depth-2 steady decode must hide plan/stage time");
        let doc = hotpath_doc(&results).to_string();
        assert!(doc.contains("hotpath_full_step"));
        assert!(doc.contains("rollback"));
        assert!(doc.contains("plan_stage_hidden_s"));
    }
}
