//! Real-execution experiments on the tiny-llm PJRT artifacts:
//! Fig. 8 (selection-overlap vs history window) and Table 1 (sparse
//! attention fidelity vs token budget).

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::engine::{drive_step, Backend, PjrtBackend, StageHints};
use crate::runtime::Runtime;
use crate::scheduler::{Batch, Phase, PrefillWork, Request};

use super::{f, render_table};

/// Build a deterministic prompt of the given length.
pub fn demo_prompt(len: usize, vocab: usize, seed: u64) -> Vec<i32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..len).map(|_| rng.below(vocab) as i32).collect()
}

/// Drive one request end-to-end on the real backend; returns the
/// generated tokens and (optionally) the per-step selection log.
pub fn generate_real(
    rt: Arc<Runtime>,
    prompt: &[i32],
    n_steps: usize,
    budget_blocks: Option<usize>,
    record_selections: bool,
) -> Result<(Vec<i32>, Vec<Vec<(u16, u16, u32)>>)> {
    let spec = rt.manifest.model.clone();
    let budget_tokens = budget_blocks
        .map(|b| b * spec.block_size)
        .unwrap_or(spec.max_ctx);
    let mut cfg = ServingConfig::sparseserve(budget_tokens, 64, spec.n_layers);
    cfg.max_inject_tokens = spec.max_ctx * spec.n_layers;
    let mut backend = PjrtBackend::new(rt, cfg, 32 << 20, 512 << 20);
    backend.record_selections = record_selections;

    let mut req = Request::with_prompt(1, prompt.to_vec(), n_steps, 0.0);
    req.phase = Phase::Prefill;
    backend.register(&req)?;
    let mut requests = HashMap::new();
    requests.insert(1u32, req);

    let batch = Batch {
        decodes: vec![],
        prefill: Some(PrefillWork::LayerSegment {
            req: 1,
            layer_start: 0,
            layer_end: spec.n_layers,
            tok_start: 0,
            tok_len: prompt.len(),
            is_last: true,
        }),
    };
    let hints = StageHints::default();
    let out = drive_step(&mut backend, &batch, &requests, &hints)?;
    let mut tokens = vec![out.tokens[0].1.unwrap()];
    requests.get_mut(&1).unwrap().phase = Phase::Decode;

    for _ in 0..n_steps.saturating_sub(1) {
        let batch = Batch { decodes: vec![1], prefill: None };
        let out = drive_step(&mut backend, &batch, &requests, &hints)?;
        tokens.push(out.tokens[0].1.unwrap());
    }
    Ok((tokens, std::mem::take(&mut backend.selection_log)))
}

/// Fig. 8: mean overlap between the current step's selected blocks and the
/// union of the preceding `w` steps, for several window sizes — measured
/// on REAL tiny-llm block selections.
pub fn fig8_overlap(rt: Arc<Runtime>) -> Result<String> {
    let spec = rt.manifest.model.clone();
    let prompt = demo_prompt(600, spec.vocab, 8);
    let (_, log) = generate_real(rt, &prompt, 40, Some(4), true)?;
    let history: Vec<HashSet<(u16, u16, u32)>> =
        log.into_iter().map(|s| s.into_iter().collect()).collect();

    let windows = [1usize, 2, 4, 8, 12, 16];
    let mut rows = Vec::new();
    let mut base = None;
    for &w in &windows {
        let mut overlaps = Vec::new();
        for s in w..history.len() {
            let cur = &history[s];
            if cur.is_empty() {
                continue;
            }
            let mut prev: HashSet<(u16, u16, u32)> = HashSet::new();
            for h in &history[s - w..s] {
                prev.extend(h.iter().copied());
            }
            overlaps.push(cur.intersection(&prev).count() as f64 / cur.len() as f64);
        }
        let mean = overlaps.iter().sum::<f64>() / overlaps.len().max(1) as f64;
        let gain = base.map(|b: f64| format!("+{:.2}%", (mean - b) * 100.0)).unwrap_or_default();
        if base.is_none() {
            base = Some(mean);
        }
        rows.push(vec![w.to_string(), format!("{:.1}%", mean * 100.0), gain]);
    }
    Ok(render_table(
        "Fig 8: selection overlap vs history window (REAL tiny-llm, budget 4 blocks)",
        &["window", "overlap", "gain vs w=1"],
        &rows,
    ))
}

/// Table 1 analog: sparse-attention output fidelity vs token budget,
/// measured as greedy-token agreement with full attention on the real
/// tiny model (the paper's claim: budget 2k ~= full-attention accuracy).
pub fn table1_accuracy(rt: Arc<Runtime>) -> Result<String> {
    let spec = rt.manifest.model.clone();
    let n_steps = 12;
    let n_prompts = 4;
    let nb = spec.max_blocks();

    // full-attention references
    let mut refs = Vec::new();
    for p in 0..n_prompts {
        let prompt = demo_prompt(300 + 60 * p, spec.vocab, 100 + p as u64);
        let (toks, _) = generate_real(rt.clone(), &prompt, n_steps, None, false)?;
        refs.push((prompt, toks));
    }

    let budgets: [(String, Option<usize>); 4] = [
        (format!("{} tok", 4 * spec.block_size), Some(4)),
        (format!("{} tok", 16 * spec.block_size), Some(16)),
        (format!("{} tok", nb * spec.block_size), Some(nb)),
        ("full".to_string(), None),
    ];
    let mut rows = Vec::new();
    for (label, budget) in &budgets {
        let mut agree = 0usize;
        let mut total = 0usize;
        for (prompt, ref_toks) in &refs {
            let (toks, _) = generate_real(rt.clone(), prompt, n_steps, *budget, false)?;
            agree += toks.iter().zip(ref_toks).filter(|(a, b)| a == b).count();
            total += ref_toks.len();
        }
        rows.push(vec![
            label.clone(),
            format!("{:.1}%", 100.0 * agree as f64 / total as f64),
            f(agree as f64),
            f(total as f64),
        ]);
    }
    Ok(render_table(
        "Table 1 analog: greedy-token agreement with full attention vs token budget (REAL tiny-llm)",
        &["budget", "agreement", "match", "steps"],
        &rows,
    ))
}
