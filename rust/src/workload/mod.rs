//! Workload generation: LongBench-like request mixes with Poisson arrivals.
//!
//! The paper combines requests from LongBench's QA, summarization and
//! code-generation tasks into one trace and draws arrival times from a
//! Poisson process with a configurable rate (§4.1). LongBench itself is
//! not redistributable here, so the generator reproduces the *shape* that
//! drives the serving dynamics: the per-task prompt/output length
//! distributions (heavy-tailed prompts, short QA answers vs long
//! summaries) and the task mix. Lengths are drawn from clamped
//! log-normals whose medians follow the LongBench per-task statistics,
//! scaled to the target model's context cap (32k for LWM-7B, 128k for
//! Llama3-8B, 2k for the tiny real-execution model).

use crate::scheduler::Request;
use crate::util::rng::Rng;

/// A LongBench-like task family (paper §4.1 workload table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Qasper / NarrativeQA / MultifieldQA / Dureader.
    QuestionAnswering,
    /// GovReport / QMSum / MultiNews / VCSum.
    Summarization,
    /// LCC / RepoBench-P.
    CodeCompletion,
}

impl TaskKind {
    pub const ALL: [TaskKind; 3] = [
        TaskKind::QuestionAnswering,
        TaskKind::Summarization,
        TaskKind::CodeCompletion,
    ];

    /// (prompt median tokens, prompt sigma, output median tokens, output
    /// sigma). Prompt medians are ABSOLUTE (LongBench document lengths do
    /// not grow with a model's context window); `WorkloadSpec.prompt_scale`
    /// shrinks them for the tiny real-execution model.
    fn profile(self) -> (f64, f64, f64, f64) {
        match self {
            // QA (Qasper/NarrativeQA/MultifieldQA/Dureader): mid-length
            // prompts, terse answers
            TaskKind::QuestionAnswering => (11_000.0, 0.6, 128.0, 0.5),
            // Summaries (GovReport/QMSum/MultiNews/VCSum): the longest
            // prompts, long outputs
            TaskKind::Summarization => (16_000.0, 0.5, 600.0, 0.4),
            // Code (LCC/RepoBench-P): shorter prompts, medium outputs
            TaskKind::CodeCompletion => (6_000.0, 0.7, 256.0, 0.5),
        }
    }
}

#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Longest admissible prompt (the paper caps 32k / 128k; tiny: ~1.5k).
    pub max_prompt: usize,
    /// Cap on generated tokens.
    pub max_output: usize,
    /// Multiplier on the absolute prompt medians (1.0 at paper scale).
    pub prompt_scale: f64,
    /// Multiplier on the output medians.
    pub output_scale: f64,
    /// Mean request arrival rate (Poisson), requests/second.
    pub rate_rps: f64,
    pub seed: u64,
    /// Logical tenants sharing the trace (1 = single-tenant; only
    /// matters with `tenant_skew > 0`).
    pub tenants: usize,
    /// Multi-tenant working-set skew in `[0, 1]`. `0.0` leaves the
    /// trace **byte-identical** to the single-tenant one (no transform,
    /// no extra RNG draws). Larger values stretch the hot tenant's
    /// (tenant 0) prompts toward the context cap, concentrating KV
    /// demand on whichever engine admits them — the knob behind the
    /// cluster goodput-vs-skew experiments.
    pub tenant_skew: f64,
}

impl WorkloadSpec {
    /// Paper-scale LWM-7B trace (32k cap).
    pub fn paper_lwm(rate_rps: f64, seed: u64) -> Self {
        Self {
            max_prompt: 32_768,
            max_output: 1024,
            prompt_scale: 1.0,
            output_scale: 1.0,
            rate_rps,
            seed,
            tenants: 1,
            tenant_skew: 0.0,
        }
    }

    /// Paper-scale Llama3-8B trace (128k cap; same absolute LongBench
    /// lengths, only the cap differs).
    pub fn paper_llama3(rate_rps: f64, seed: u64) -> Self {
        Self {
            max_prompt: 131_072,
            max_output: 1024,
            prompt_scale: 1.0,
            output_scale: 1.0,
            rate_rps,
            seed,
            tenants: 1,
            tenant_skew: 0.0,
        }
    }

    /// Tiny trace for the real PJRT backend (2k ctx model).
    pub fn tiny(rate_rps: f64, seed: u64) -> Self {
        Self {
            max_prompt: 1500,
            max_output: 24,
            prompt_scale: 1500.0 / 32_768.0,
            output_scale: 0.12,
            rate_rps,
            seed,
            tenants: 1,
            tenant_skew: 0.0,
        }
    }

    /// Multi-tenant skew knob (see [`WorkloadSpec::tenant_skew`]).
    pub fn with_tenant_skew(mut self, tenants: usize, skew: f64) -> Self {
        self.tenants = tenants.max(1);
        self.tenant_skew = skew.clamp(0.0, 1.0);
        self
    }
}

/// Generate `n` requests with Poisson arrivals and mixed task lengths.
/// Ids start at `id_base`. Uses independent RNG streams for arrivals vs
/// lengths so the arrival process is invariant to length parameters.
pub fn generate(spec: &WorkloadSpec, n: usize, id_base: u32) -> Vec<Request> {
    let mut arr_rng = Rng::with_stream(spec.seed, 101);
    let mut len_rng = Rng::with_stream(spec.seed, 202);
    let mut t = 0.0;
    let mut reqs: Vec<Request> = (0..n)
        .map(|i| {
            t += arr_rng.exponential(spec.rate_rps);
            let task = *len_rng.choose(&TaskKind::ALL);
            let (pm, ps, om, os) = task.profile();
            let prompt_len = (len_rng
                .lognormal((pm * spec.prompt_scale).max(16.0).ln(), ps)
                .round() as usize)
                .clamp(16, spec.max_prompt);
            let out = (len_rng.lognormal((om * spec.output_scale).max(2.0).ln(), os).round()
                as usize)
                .clamp(2, spec.max_output);
            Request::new(id_base + i as u32, prompt_len, out, t)
        })
        .collect();
    apply_tenant_skew(spec, &mut reqs);
    reqs
}

/// Stretch the hot tenant's prompts toward the context cap. Tenant
/// assignment draws from a dedicated RNG stream (505), so arrivals and
/// the base length mix are invariant to the knob; with `tenant_skew ==
/// 0.0` (or a single tenant) NOTHING runs and the trace stays
/// byte-identical to the single-tenant one.
fn apply_tenant_skew(spec: &WorkloadSpec, reqs: &mut [Request]) {
    if spec.tenants <= 1 || spec.tenant_skew <= 0.0 {
        return;
    }
    let mut ten_rng = Rng::with_stream(spec.seed, 505);
    for r in reqs {
        if ten_rng.below(spec.tenants) == 0 {
            let stretched =
                (r.prompt_len as f64 * (1.0 + 3.0 * spec.tenant_skew)).round() as usize;
            r.prompt_len = stretched.clamp(16, spec.max_prompt);
        }
    }
}

/// Same trace but with concrete (deterministic) prompt token ids for the
/// real backend.
pub fn generate_with_tokens(spec: &WorkloadSpec, n: usize, id_base: u32, vocab: usize) -> Vec<Request> {
    let mut reqs = generate(spec, n, id_base);
    let mut tok_rng = Rng::with_stream(spec.seed, 303);
    for r in &mut reqs {
        r.prompt = (0..r.prompt_len)
            .map(|_| tok_rng.below(vocab) as i32)
            .collect();
    }
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let spec = WorkloadSpec::paper_lwm(0.1, 7);
        let a = generate(&spec, 20, 0);
        let b = generate(&spec, 20, 0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }

    #[test]
    fn arrivals_are_poisson_with_rate() {
        let spec = WorkloadSpec::paper_lwm(0.25, 3);
        let reqs = generate(&spec, 2000, 0);
        let span = reqs.last().unwrap().arrival_s;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 0.25).abs() < 0.02, "rate={rate}");
        // monotone arrivals
        assert!(reqs.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn lengths_respect_caps() {
        let spec = WorkloadSpec::paper_llama3(0.2, 11);
        for r in generate(&spec, 500, 0) {
            assert!(r.prompt_len >= 16 && r.prompt_len <= spec.max_prompt);
            assert!(r.max_new_tokens >= 2 && r.max_new_tokens <= spec.max_output);
        }
    }

    #[test]
    fn mix_is_heterogeneous() {
        let spec = WorkloadSpec::paper_lwm(0.2, 5);
        let reqs = generate(&spec, 300, 0);
        let mean = reqs.iter().map(|r| r.prompt_len).sum::<usize>() / reqs.len();
        let long = reqs.iter().filter(|r| r.prompt_len > 2 * mean).count();
        let short = reqs.iter().filter(|r| r.prompt_len < mean / 2).count();
        assert!(long > 0 && short > 0, "length mix must be heavy-tailed");
    }

    #[test]
    fn zero_tenant_skew_is_byte_identical() {
        let base = WorkloadSpec::paper_lwm(0.1, 7);
        let multi = WorkloadSpec::paper_lwm(0.1, 7).with_tenant_skew(4, 0.0);
        for (x, y) in generate(&base, 50, 0).iter().zip(&generate(&multi, 50, 0)) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }

    #[test]
    fn tenant_skew_concentrates_prompt_demand_without_touching_arrivals() {
        let base = WorkloadSpec::paper_lwm(0.1, 7);
        let skewed = WorkloadSpec::paper_lwm(0.1, 7).with_tenant_skew(4, 0.8);
        let a = generate(&base, 300, 0);
        let b = generate(&skewed, 300, 0);
        let (mut grew, mut same) = (0usize, 0usize);
        let (mut sum_a, mut sum_b) = (0usize, 0usize);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s, "arrival process is invariant");
            assert!(y.prompt_len >= x.prompt_len, "stretch never shrinks a prompt");
            assert!(y.prompt_len <= skewed.max_prompt);
            if y.prompt_len > x.prompt_len {
                grew += 1;
            } else {
                same += 1;
            }
            sum_a += x.prompt_len;
            sum_b += y.prompt_len;
        }
        // ~1/4 of requests belong to the hot tenant and stretch; the
        // cold tenants stay untouched
        assert!(grew > 30 && same > 150, "grew={grew} same={same}");
        assert!(sum_b > sum_a, "skew must concentrate aggregate KV demand");
    }

    #[test]
    fn tokens_in_vocab() {
        let spec = WorkloadSpec::tiny(1.0, 9);
        for r in generate_with_tokens(&spec, 20, 100, 256) {
            assert_eq!(r.prompt.len(), r.prompt_len);
            assert!(r.prompt.iter().all(|&t| (0..256).contains(&t)));
            assert!(r.id >= 100);
        }
    }
}
