//! Fig. 15: throughput & KV loads with/without working-set-aware batch
//! size control, across request rates.
fn main() {
    println!("{}", sparseserve::figures::sim_exp::fig15(&[0.1, 0.2, 0.3, 0.4, 0.5]));
}
