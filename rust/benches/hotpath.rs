//! Hot-path microbenchmarks (§Perf): the L3 operations on the decode
//! critical path, plus the FULL-STEP pipeline (plan → stage → per-layer
//! decode → commit on the SimBackend, hybrid, and rollback+retry) from
//! `figures::hotpath`. Targets from DESIGN.md §Perf: scheduler decision
//! < 10 µs/request, top-k (128 blocks) < 5 µs, engine overhead small
//! relative to modeled PCIe time. The same full-step suite backs the
//! `bench` subcommand's `BENCH_hotpath.json` CI artifact.

use std::sync::Arc;

use sparseserve::config::serving::TransferKind;
use sparseserve::config::{HardwareSpec, ModelSpec, ServingConfig};
use sparseserve::memory::transfer::{engine_for, ScatterEntry};
use sparseserve::memory::{BlockPool, LruCache};
use sparseserve::scheduler::{Phase, Request, Scheduler};
use sparseserve::sim::SelectionModel;
use sparseserve::sparse::{top_k_blocks, top_k_blocks_fast};
use sparseserve::util::bench::bench;
use sparseserve::util::rng::Rng;

fn main() {
    let mut results = Vec::new();

    // ---- top-k selection ----
    let mut rng = Rng::new(1);
    let scores: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
    results.push(bench("topk/128 blocks k=63 (sort)", 0.4, 100, || {
        std::hint::black_box(top_k_blocks(&scores, 128, 63));
    }));
    results.push(bench("topk/128 blocks k=63 (fast)", 0.4, 100, || {
        std::hint::black_box(top_k_blocks_fast(&scores, 128, 63));
    }));
    let scores_big: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
    results.push(bench("topk/1024 blocks k=64 (paper scale)", 0.4, 100, || {
        std::hint::black_box(top_k_blocks_fast(&scores_big, 1024, 64));
    }));
    let mut topk_buf = Vec::new();
    results.push(bench("topk/1024 blocks k=64 (fast, into scratch)", 0.4, 100, || {
        sparseserve::sparse::top_k_blocks_fast_into(&scores_big, 1024, 64, &mut topk_buf);
        std::hint::black_box(topk_buf.len());
    }));

    // ---- scheduler plan (Alg. 1) ----
    let spec = ModelSpec::lwm_7b();
    let cfg = ServingConfig::sparseserve(2048, 2048, 32);
    let mut sched = Scheduler::new(cfg, spec.clone(), 18 << 30);
    for id in 0..32u32 {
        let mut r = Request::new(id, 8192, 256, 0.0);
        r.phase = Phase::Decode;
        sched.submit(r);
    }
    // move them to active decode state
    {
        let mut ws = |_| 0usize;
        for _ in 0..40 {
            let b = sched.plan(0.0, &mut ws);
            if let Some(w) = b.prefill {
                let last = w.is_last();
                sched.advance_prefill(&w);
                if last {
                    sched.emit_token(w.req(), None, 0.0);
                }
            }
        }
    }
    results.push(bench("scheduler/plan 32 decodes + Alg.1", 0.4, 20, || {
        let mut ws = |_| 500 << 20;
        std::hint::black_box(sched.plan(0.0, &mut ws));
    }));

    // ---- LRU cache ops ----
    let mut cache: LruCache<u32> = LruCache::new(1024);
    let mut i = 0u32;
    results.push(bench("lru/get+insert cycle", 0.3, 100, || {
        let key = sparseserve::memory::BlockKey::new(0, 0, 0, i % 2048);
        if cache.get(&key).is_none() {
            cache.insert(key, i);
        }
        i += 1;
    }));

    // ---- transfer engines (real copies, 16 KB paper blocks) ----
    let mut dram = BlockPool::new(256, 32, 128);
    let mut hbm = BlockPool::new(256, 32, 128);
    let pairs: Vec<_> = (0..64)
        .map(|_| (dram.alloc().unwrap(), hbm.alloc().unwrap()))
        .collect();
    let hw = HardwareSpec::a100_40gb();
    let flash = engine_for(TransferKind::Flash, hw.clone());
    let memcpy = engine_for(TransferKind::Memcpy, hw);
    results.push(bench("transfer/flash-load 64x16KB", 0.4, 10, || {
        std::hint::black_box(flash.load(&dram, &mut hbm, &pairs));
    }));
    results.push(bench("transfer/memcpy-load 64x16KB", 0.4, 10, || {
        std::hint::black_box(memcpy.load(&dram, &mut hbm, &pairs));
    }));
    let src = vec![0.3f32; 64 * dram.slot_floats()];
    let entries: Vec<ScatterEntry> = pairs
        .iter()
        .enumerate()
        .map(|(i, (dslot, _))| ScatterEntry {
            src_off: i * dram.slot_floats(),
            len: dram.slot_floats(),
            dst_slot: *dslot,
            dst_off: 0,
        })
        .collect();
    results.push(bench("transfer/flash-save 64x16KB (stage+scatter)", 0.4, 10, || {
        std::hint::black_box(flash.save(&src, &mut dram, &entries));
    }));

    // ---- selection model step (sim hot loop) ----
    let mut sel = SelectionModel::new(3);
    results.push(bench("sim/selection step 1024 blocks budget 64", 0.3, 20, || {
        std::hint::black_box(sel.next_selection(1024, 64));
    }));
    let mut sel2 = SelectionModel::new(3);
    let mut sel_buf = Vec::new();
    results.push(bench("sim/selection step (into scratch)", 0.3, 20, || {
        sel2.next_selection_into(1024, 64, &mut sel_buf);
        std::hint::black_box(sel_buf.len());
    }));

    // ---- full-step pipeline (plan → stage → layers → commit) ----
    results.extend(sparseserve::figures::full_step_results(0.4));

    // ---- real decode step, if artifacts exist ----
    let dir = sparseserve::runtime::Runtime::default_dir("tiny-llm");
    if dir.join("manifest.json").exists() {
        use sparseserve::engine::{drive_step, Backend, PjrtBackend, StageHints};
        use sparseserve::scheduler::Batch;
        use std::collections::HashMap;

        let rt = Arc::new(sparseserve::runtime::Runtime::load(dir).unwrap());
        let tspec = rt.manifest.model.clone();
        let mut tcfg = ServingConfig::sparseserve(256, 64, tspec.n_layers);
        tcfg.max_inject_tokens = tspec.max_ctx * tspec.n_layers;
        let mut backend = PjrtBackend::new(rt, tcfg, 8 << 20, 512 << 20);
        let prompt = sparseserve::figures::real::demo_prompt(300, tspec.vocab, 5);
        let mut req = Request::with_prompt(1, prompt.clone(), 4096, 0.0);
        req.phase = Phase::Prefill;
        backend.register(&req).unwrap();
        let mut requests = HashMap::new();
        requests.insert(1u32, req);
        let pf = Batch {
            decodes: vec![],
            prefill: Some(sparseserve::scheduler::PrefillWork::LayerSegment {
                req: 1, layer_start: 0, layer_end: tspec.n_layers,
                tok_start: 0, tok_len: prompt.len(), is_last: true,
            }),
        };
        let hints = StageHints::default();
        drive_step(&mut backend, &pf, &requests, &hints).unwrap();
        requests.get_mut(&1).unwrap().phase = Phase::Decode;
        let db = Batch { decodes: vec![1], prefill: None };
        results.push(bench("e2e/real decode step B=1 (4 layers, PJRT)", 2.0, 3, || {
            std::hint::black_box(drive_step(&mut backend, &db, &requests, &hints).unwrap());
        }));
    }

    println!("== hotpath microbenchmarks ==");
    for r in &results {
        println!("{}", r.line());
    }
}
