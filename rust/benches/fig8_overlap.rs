//! Fig. 8: block-selection overlap vs history window (REAL tiny-llm).
use std::sync::Arc;
use sparseserve::runtime::Runtime;

fn main() {
    let dir = Runtime::default_dir("tiny-llm");
    if !dir.join("manifest.json").exists() {
        println!("fig8 skipped: run `make artifacts` first");
        return;
    }
    let rt = Arc::new(Runtime::load(dir).expect("artifacts"));
    println!("{}", sparseserve::figures::fig8_overlap(rt).unwrap());
}
