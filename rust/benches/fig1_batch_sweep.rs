//! Fig. 1: decode throughput & KV blocks loaded/iter vs batch size.
fn main() {
    println!("{}", sparseserve::figures::sim_exp::fig1());
}
