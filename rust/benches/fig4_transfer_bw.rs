//! Fig. 4: PCIe bandwidth of KV loading/saving vs block size — the
//! calibrated model series plus a REAL measurement of this repo's
//! transfer engines moving bytes between the host block pools.

use std::time::Instant;

use sparseserve::config::serving::TransferKind;
use sparseserve::config::HardwareSpec;
use sparseserve::memory::transfer::{engine_for, ScatterEntry};
use sparseserve::memory::BlockPool;

fn main() {
    println!("{}", sparseserve::figures::sim_exp::fig4());

    // Real engine wall-clock throughput (host-memory copies, this machine)
    println!("== Fig 4 (real engines, host-memory wall clock on this machine) ==");
    println!("{:>8} {:>16} {:>16} {:>16}", "block", "memcpy GB/s", "flash-load GB/s", "flash-save GB/s");
    for &(bs, dh) in &[(8usize, 64usize), (16, 64), (32, 64), (32, 128)] {
        let n = 256;
        let mut dram = BlockPool::new(n, bs, dh);
        let mut hbm = BlockPool::new(n, bs, dh);
        let pairs: Vec<_> = (0..n).map(|_| (dram.alloc().unwrap(), hbm.alloc().unwrap())).collect();
        let block_bytes = dram.slot_bytes();
        let hw = HardwareSpec::a100_40gb();
        let mem = engine_for(TransferKind::Memcpy, hw.clone());
        let fla = engine_for(TransferKind::Flash, hw);

        let time_it = |f: &mut dyn FnMut()| {
            let reps = 20;
            let t0 = Instant::now();
            for _ in 0..reps {
                f();
            }
            t0.elapsed().as_secs_f64() / reps as f64
        };
        let t_mem = time_it(&mut || {
            mem.load(&dram, &mut hbm, &pairs);
        });
        let t_fla = time_it(&mut || {
            fla.load(&dram, &mut hbm, &pairs);
        });
        let src = vec![1.0f32; n * dram.slot_floats()];
        let entries: Vec<ScatterEntry> = pairs
            .iter()
            .enumerate()
            .map(|(i, (d, _))| ScatterEntry {
                src_off: i * dram.slot_floats(),
                len: dram.slot_floats(),
                dst_slot: *d,
                dst_off: 0,
            })
            .collect();
        let t_save = time_it(&mut || {
            fla.save(&src, &mut dram, &entries);
        });
        let total = (n * block_bytes) as f64 / 1e9;
        println!(
            "{:>6}KB {:>16.2} {:>16.2} {:>16.2}",
            block_bytes / 1024,
            total / t_mem,
            total / t_fla,
            total / t_save
        );
    }
}
