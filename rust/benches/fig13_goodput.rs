//! Fig. 13: goodput (max sustainable rate under SLO) for the ablation
//! ladder vLLM -> +SA -> +Offload -> +FT -> +WC -> +LP.
fn main() {
    println!("{}", sparseserve::figures::sim_exp::fig13("lwm-7b"));
    println!("{}", sparseserve::figures::sim_exp::fig13("llama3-8b"));
}
