//! Fig. 16: (a) TTFT of chunked vs layer-segmented prefill across rates;
//! (b) prefill attention overhead vs chunk size.
fn main() {
    println!("{}", sparseserve::figures::sim_exp::fig16a(&[0.05, 0.15, 0.25, 0.35]));
    println!("{}", sparseserve::figures::sim_exp::fig16b());
}
