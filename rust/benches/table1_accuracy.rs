//! Table 1 analog: sparse-attention output fidelity vs token budget on
//! the REAL tiny-llm model.
use std::sync::Arc;
use sparseserve::runtime::Runtime;

fn main() {
    let dir = Runtime::default_dir("tiny-llm");
    if !dir.join("manifest.json").exists() {
        println!("table1 skipped: run `make artifacts` first");
        return;
    }
    let rt = Arc::new(Runtime::load(dir).expect("artifacts"));
    println!("{}", sparseserve::figures::table1_accuracy(rt).unwrap());
}
