//! Figs. 10-12: mean TTFT, token throughput, mean TBT vs request rate for
//! vLLM / vLLM-S / vLLM-SO / SparseServe on both paper models (simulated
//! A100 testbed).
use sparseserve::figures::sim_exp::{default_rates, fig10_11_12};

fn main() {
    for model in ["lwm-7b", "llama3-8b"] {
        println!("{}", fig10_11_12(model, &default_rates(model)));
    }
}
