//! Fig. 14: (a) decode batch/loading latency memcpy vs FlashH2D;
//! (b) prefill latency by KV saving method.
fn main() {
    println!("{}", sparseserve::figures::sim_exp::fig14a());
    println!("{}", sparseserve::figures::sim_exp::fig14b());
}
